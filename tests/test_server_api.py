"""Session-oriented serving API (repro.serving.server): handle/stream/cancel
semantics, multi-turn session state reuse, per-request RNG reproducibility,
stop sequences, priority classes, and the deprecation shim — with the
acceptance invariant that greedy outputs through ``LLMServer`` are
bit-identical to the pre-redesign ``ServingEngine.generate`` in dense,
paged, and snapshot cache modes."""
import pytest

from repro.configs.registry import ARCHS
from repro.serving.engine import ServingEngine
from repro.serving.scheduler import SamplingParams, Scheduler
from repro.serving.server import EngineConfig, LLMServer

from tests._hypothesis_compat import given, settings, st


def _cfg(arch):
    return ARCHS[arch].reduced(dtype="float32", param_dtype="float32",
                               vocab_size=512)


@pytest.fixture(scope="module")
def qwen():
    return _cfg("qwen2.5-3b")


@pytest.fixture(scope="module")
def qwen_params(qwen):
    from repro.models import Model
    import jax
    return Model(qwen).init(jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# acceptance: LLMServer == pre-redesign ServingEngine.generate, greedy,
# across all three cache modes — and concurrent handles co-batch
# ---------------------------------------------------------------------------

MODES = [("qwen2.5-3b", "dense"), ("qwen2.5-3b", "paged"),
         ("recurrentgemma-9b", "paged")]          # paged resolves: pages/snaps

PROMPTS = ["alpha prompt for slot one",
           "a rather longer second prompt that crosses a bucket",
           "third prompt"]


@pytest.mark.parametrize("arch,mode", MODES)
def test_server_greedy_bit_identical_to_engine(arch, mode):
    cfg = _cfg(arch)
    ecfg = EngineConfig(cache_mode=mode, page_size=16)
    eng = ServingEngine(cfg, num_slots=3, capacity=128, engine_cfg=ecfg)
    with pytest.warns(DeprecationWarning):
        ref = [eng.generate(p, max_new_tokens=8) for p in PROMPTS]
    srv = LLMServer(cfg, num_slots=3, capacity=128, params=eng.params,
                    engine_cfg=ecfg)
    handles = [srv.submit(p, SamplingParams(max_new_tokens=8))
               for p in PROMPTS]                  # all queued before any runs
    srv.run_until_idle()
    assert [h.result() for h in handles] == ref, (arch, mode)
    # the three concurrent handles actually shared engine steps
    assert srv.stats()["active_slots_per_step"] > 1.0


# ---------------------------------------------------------------------------
# sessions: multi-turn reuse at non-block-aligned boundaries, bit-identical
# ---------------------------------------------------------------------------

SYS = "System: cooperating agents share this conversation verbatim. "
TURNS = ["[planner] Plan the next step of the task. ",
         "[actor] Act: call the search tool now. ",
         "[evaluator] Evaluate the tool output please. "]


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "recurrentgemma-9b"])
def test_session_multi_turn_reuse_bit_identical(arch):
    """Turn N+1 restores turn N's end-of-generation state (partial tail
    page / tail snapshot — NON-block-aligned) and prefills only the new
    message; greedy outputs must equal a fresh engine fed the exact same
    token stream."""
    cfg = _cfg(arch)
    ps = 16
    srv = LLMServer(cfg, num_slots=2, capacity=192,
                    engine_cfg=EngineConfig(cache_mode="paged", page_size=ps))
    fresh = Scheduler(cfg, num_slots=2, capacity=192, params=srv.params)
    sess = srv.open_session()
    sp = SamplingParams(max_new_tokens=9)
    prompt = SYS
    hits, tails = [], []
    for turn in TURNS:
        prompt = sess.text + turn if sess.text else prompt + turn
        tails.append(srv.engine._sessions[sess.sid].tail_len)
        h = sess.submit(prompt, sp)
        out = h.result()
        r = fresh.enqueue(prompt, sp, token_ids=h.request._ids)
        fresh.run_until_drained()
        assert r.output_text == out, (arch, turn)
        hits.append(h.request.prefix_hit_tokens)
    st = srv.stats()
    assert st["session_turns"] == 3
    assert st["turn_prefix_hits"] >= 2           # every later turn reused
    # each later turn restored EXACTLY the previous end-of-generation
    # boundary (prompt + generated) — non-block-aligned, which a radix hit
    # alone cannot reach
    assert hits[1:] == tails[1:], (hits, tails)
    assert any(t % ps for t in tails[1:]), tails
    sess.close()


def test_session_dense_mode_token_exact_no_reuse(qwen, qwen_params):
    """Dense cache mode has nothing to retain, but session turns must still
    continue the exact token stream (prompt + generated), matching a fresh
    engine fed the same ids — with zero prefix hits."""
    srv = LLMServer(qwen, num_slots=2, capacity=192, params=qwen_params)
    fresh = Scheduler(qwen, num_slots=2, capacity=192, params=qwen_params)
    sess = srv.open_session()
    sp = SamplingParams(max_new_tokens=7)
    prompt = SYS + TURNS[0]
    for turn in TURNS[1:]:
        out = sess.submit(prompt, sp).result()
        prompt = sess.text + turn
    h = sess.submit(prompt, sp)
    out = h.result()
    assert len(h.request._ids) > len(srv.engine.tokenizer.encode(TURNS[-1]))
    r = fresh.enqueue(prompt, sp, token_ids=h.request._ids)
    fresh.run_until_drained()
    assert r.output_text == out
    assert h.request.prefix_hit_tokens == 0
    sess.close()


def test_session_history_rewrite_falls_back(qwen, qwen_params):
    """A turn that does NOT extend the session's conversation resets the
    retained tail and still serves correctly."""
    srv = LLMServer(qwen, num_slots=2, capacity=128, params=qwen_params,
                    engine_cfg=EngineConfig(cache_mode="paged"))
    sess = srv.open_session()
    sp = SamplingParams(max_new_tokens=6)
    sess.submit(SYS + TURNS[0], sp).result()
    rewritten = "totally different conversation history. " + TURNS[1]
    out = sess.submit(rewritten, sp).result()
    eng = Scheduler(qwen, num_slots=2, capacity=128, params=qwen_params)
    ref = eng.enqueue(rewritten, sp)
    eng.run_until_drained()
    assert out == ref.output_text
    sess.close()
    # everything the session retained was released on reset/close
    eng2 = srv.engine
    owned = eng2.radix.check_invariants()
    free = set(eng2.kvpool._free)
    assert len(owned) + len(free) == eng2.kvpool.num_pages - eng2.kvpool.reserved


def test_session_single_turn_in_flight(qwen, qwen_params):
    srv = LLMServer(qwen, num_slots=2, capacity=96, params=qwen_params)
    sess = srv.open_session()
    sess.submit("first turn", SamplingParams(max_new_tokens=4))
    with pytest.raises(RuntimeError):
        sess.submit("second turn before the first drained",
                    SamplingParams(max_new_tokens=4))
    srv.run_until_idle()
    sess.close()


# ---------------------------------------------------------------------------
# streaming
# ---------------------------------------------------------------------------


def test_stream_utf8_holdback_boundaries():
    """A multi-byte UTF-8 character split across chunk syncs must be held
    back until complete: at every boundary the holdback allows, the split
    decode equals the full decode (so the concatenated stream equals
    ``result()`` byte-for-byte)."""
    from repro.serving.server import _utf8_holdback
    from repro.serving.tokenizer import ByteTokenizer
    tok = ByteTokenizer(512)
    streams = [
        list("café!".encode()),                  # 2-byte char
        list("a€ b".encode()),                   # 3-byte char
        list("x\U0001f600y".encode()),                # 4-byte char
        [ord("a"), 0xC3],                             # ends mid-sequence
        [ord("a"), 0xE2, 0x82],                       # ends mid-3-byte
        [0x80, 0x80, ord("b")],                       # stray continuations
        [0xC0, 0x80, ord("c")],                       # invalid lead
        [260, 0xC3, 0xA9, 261],                       # merges around a char
    ]
    for ids in streams:
        full = tok.decode(ids)
        for k in range(len(ids) + 1):
            hb = _utf8_holdback(ids[:k])
            cut = k - hb
            assert tok.decode(ids[:cut]) + tok.decode(ids[cut:]) == full, \
                (ids, k, hb)
        # the holdback never withholds a complete stream
        assert _utf8_holdback(ids) <= 3


def test_jaxllm_concurrent_same_role_falls_back(qwen, qwen_params):
    """Two concurrent workflows sharing one role prompt must both serve:
    the second submit finds the role's session busy and degrades to a
    sessionless (still co-batched) request instead of raising."""
    from repro.core.llm import JaxLLM
    srv = LLMServer(qwen, num_slots=2, capacity=96, params=qwen_params)
    llm = JaxLLM(srv, max_new_tokens=5)
    h1 = llm.submit("shared planner prompt", "workflow one context")
    h2 = llm.submit("shared planner prompt", "workflow two context")
    srv.run_until_idle()
    h1.result(), h2.result()
    assert h1.request.output_tokens == h2.request.output_tokens == 5
    assert srv.stats()["sessions_opened"] == 1


@pytest.mark.parametrize("arch,want_drafts", [
    ("qwen2.5-3b", True),           # copy prompts reliably draft on qwen
    ("recurrentgemma-9b", False),   # stateful tail-snapshot path; untrained
])                                  # weights may not reach the copy regime
def test_session_with_spec_decode_matches_fresh(arch, want_drafts):
    """Sessions + speculative decoding: the tail state restored by turn N+1
    must reflect exactly the kept tokens even when verify commits drafts,
    staying bit-identical to a fresh engine on the same stream."""
    cfg = _cfg(arch)
    srv = LLMServer(cfg, num_slots=2, capacity=256,
                    engine_cfg=EngineConfig(cache_mode="paged", page_size=16,
                                            spec_len=6))
    fresh = Scheduler(cfg, num_slots=2, capacity=256, params=srv.params)
    sess = srv.open_session()
    sp = SamplingParams(max_new_tokens=32)
    prompt = SYS + "Tool result: ERROR 429 rate limit exceeded at gateway. " * 2
    for turn in TURNS[:2]:
        prompt = (sess.text or prompt) + turn
        h = sess.submit(prompt, sp)
        out = h.result()
        r = fresh.enqueue(prompt, sp, token_ids=h.request._ids)
        fresh.run_until_drained()
        assert r.output_text == out, (arch, turn)
    if want_drafts:
        assert srv.stats()["draft_tokens"] > 0   # speculation actually ran
    sess.close()


def test_spec_eos_truncation_skips_tail_snapshot():
    """Regression: a spec accept truncated at EOS leaves the device state
    ahead of the kept tokens (verify_commit rewound to the full accepted
    length) — the session tail snapshot for that turn must be SKIPPED, not
    captured from the over-advanced state."""
    cfg = _cfg("recurrentgemma-9b")
    srv = LLMServer(cfg, num_slots=1, capacity=128,
                    engine_cfg=EngineConfig(cache_mode="paged", page_size=16,
                                            spec_len=5, decode_chunk=2))
    eng = srv.engine
    sess = srv.open_session()
    h = sess.submit(SYS + TURNS[0], SamplingParams(max_new_tokens=40))
    srv.step()                                   # admit + one decode chunk
    slot = eng.slots[0]
    assert slot.request is h.request
    eos = eng.tokenizer.eos_id
    # simulate a verify outcome whose 4 emitted tokens contain EOS at
    # position 1: the host keeps 2 tokens, the device state processed 4
    eng._commit_spec(0, [1, 2, 3], [7, eos, 9, 10], 4, 0.0)
    assert h.request.finished                    # EOS ended the request
    assert h.request.output_ids[-1] == eos
    st = eng._sessions[sess.sid]
    assert st.tail_snap == -1                    # capture skipped
    assert st.all_tokens == h.request._ids + h.request.output_ids
    # the conversation still continues correctly off the radix/trie path
    h2 = sess.submit(st.text + TURNS[1], SamplingParams(max_new_tokens=6))
    fresh = Scheduler(cfg, num_slots=1, capacity=128, params=srv.params)
    out = h2.result()
    r = fresh.enqueue("", SamplingParams(max_new_tokens=6),
                      token_ids=h2.request._ids)
    fresh.run_until_drained()
    assert r.output_text == out
    sess.close()


def test_stream_increments_concatenate_to_result(qwen, qwen_params):
    srv = LLMServer(qwen, num_slots=2, capacity=96, params=qwen_params,
                    engine_cfg=EngineConfig(decode_chunk=2))
    h = srv.submit("stream me some text please",
                   SamplingParams(max_new_tokens=12))
    pieces = list(h.stream())
    assert len(pieces) >= 2                       # incremental, not one blob
    assert "".join(pieces) == h.result() == h.text
    assert h.status().value == "completed"
    assert srv.stats()["stream_chunks"] >= len(pieces)


# ---------------------------------------------------------------------------
# cancellation: queued / mid-flight, slot + page accounting, leak property
# ---------------------------------------------------------------------------


def test_cancel_queued_and_midflight(qwen, qwen_params):
    srv = LLMServer(qwen, num_slots=1, capacity=128, params=qwen_params,
                    engine_cfg=EngineConfig(cache_mode="paged",
                                            decode_chunk=2))
    a = srv.submit("request a " * 3, SamplingParams(max_new_tokens=24))
    b = srv.submit("request b " * 3, SamplingParams(max_new_tokens=24))
    srv.step()                                    # admit a, decode one chunk
    assert a.status().value == "running" and b.status().value == "queued"
    assert srv.cancel(b) and b.status().value == "cancelled"
    partial = a.text
    assert srv.cancel(a) and a.status().value == "cancelled"
    assert a.result() == a.text and a.text.startswith(partial)
    assert a.request.output_tokens > 0            # partial output kept
    assert not srv.cancel(a)                      # idempotent: already done
    c = srv.submit("request c", SamplingParams(max_new_tokens=4))
    c.result()                                    # freed slot is reusable
    eng = srv.engine
    st = eng.stats()
    assert st["cancelled_requests"] == 2
    assert all(s.request is None for s in eng.slots)
    owned = eng.radix.check_invariants()
    free = set(eng.kvpool._free)
    assert not (owned & free)
    assert len(owned) + len(free) == eng.kvpool.num_pages - eng.kvpool.reserved


def test_cancel_snapshot_mode_accounting():
    """Mid-flight cancel on a stateful arch releases the pin and keeps the
    session's retained tail for a retried turn."""
    cfg = _cfg("recurrentgemma-9b")
    srv = LLMServer(cfg, num_slots=1, capacity=128,
                    engine_cfg=EngineConfig(cache_mode="paged",
                                            decode_chunk=2))
    sess = srv.open_session()
    sess.submit(SYS + TURNS[0], SamplingParams(max_new_tokens=8)).result()
    tail_before = srv.engine._sessions[sess.sid].tail_snap
    assert tail_before >= 0
    h = sess.submit(sess.text + TURNS[1], SamplingParams(max_new_tokens=24))
    srv.step()
    assert srv.cancel(h)
    # the retained tail survived the cancelled turn — retry reuses it
    assert srv.engine._sessions[sess.sid].tail_snap == tail_before
    h2 = sess.submit(sess.text + TURNS[1], SamplingParams(max_new_tokens=8))
    out = h2.result()
    assert out and h2.request.prefix_hit_tokens > 0
    sess.close()
    eng = srv.engine
    owned = eng.radix.check_invariants(snapshots=True)
    free = set(eng.snaps._free)
    assert not (owned & free)
    assert len(owned) + len(free) == eng.snaps.num_snaps


_CANCEL_SRV = None


def _cancel_server():
    global _CANCEL_SRV
    if _CANCEL_SRV is None:
        # tiny pool (eviction pressure) + spec (rejection pressure) + tiny
        # chunks (many cancel windows) — the PR-3 page-leak test's twin,
        # now under random cancel + session-tail pressure
        _CANCEL_SRV = LLMServer(
            _cfg("qwen2.5-3b"), num_slots=2, capacity=64,
            engine_cfg=EngineConfig(cache_mode="paged", page_size=8,
                                    num_pages=18, spec_len=4,
                                    decode_chunk=4))
    return _CANCEL_SRV


def _cancel_leak_check(srv):
    eng = srv.engine
    assert all(s.request is None for s in eng.slots)
    owned = eng.radix.check_invariants()
    free = set(eng.kvpool._free)
    tails = {s.tail_page for s in eng._sessions.values() if s.tail_page >= 0}
    assert not (owned & free) and not (owned & tails) and not (free & tails)
    # exactly-once ownership: free list, radix tree, or a session tail
    assert (len(owned) + len(free) + len(tails)
            == eng.kvpool.num_pages - eng.kvpool.reserved)


@given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 3),
                          st.integers(2, 12)),
                min_size=3, max_size=10))
@settings(max_examples=40, deadline=None)
def test_cancel_no_page_leak(ops):
    """Random submit / session-turn / step / cancel interleavings (shared
    prefixes, LRU eviction from the deliberately tiny pool, draft
    rejections, retained session tails): after every drain each page is
    owned exactly once — free list, radix tree, or a session tail — so
    cancellation mid-prefill/mid-decode/mid-verify never leaks or
    double-frees."""
    srv = _cancel_server()
    sess = srv.open_session()
    pool = ["err 429 err 429 err 429. " + t for t in
            ("", "tail one", "go go go go go", "a longer tail that repeats")]
    handles = []
    for kind, variant, budget in ops:
        if kind == 0:
            handles.append(srv.submit(pool[variant],
                                      SamplingParams(max_new_tokens=budget)))
        elif kind == 1:
            live = srv.engine._sessions[sess.sid].live
            if live is None or live.finished:
                prompt = (sess.text or pool[variant]) + f" turn {variant}"
                handles.append(sess.submit(
                    prompt, SamplingParams(max_new_tokens=budget)))
        elif kind == 2:
            for _ in range(variant + 1):
                srv.step()
        elif handles:
            srv.cancel(handles[-(1 + variant % len(handles))])
    srv.run_until_idle()
    sess.close()
    _cancel_leak_check(srv)


def test_cancel_leak_server_exercised():
    """Companion gate (and no-hypothesis fallback): the shared cancel server
    must actually cancel mid-flight, evict, and retain session tails — a
    run that never cancelled anything live would make the property above
    vacuous."""
    import random
    srv = _cancel_server()
    rng = random.Random(0)
    mid_cancels = 0
    for _ in range(6):
        hs = [srv.submit("err 429 err 429 err 429. tail " + str(rng.randrange(3)),
                         SamplingParams(max_new_tokens=rng.randint(4, 16)))
              for _ in range(rng.randint(2, 5))]
        srv.step()
        victim = rng.choice(hs)
        if victim.status().value == "running":
            mid_cancels += 1
        srv.cancel(victim)
        srv.run_until_idle()
        _cancel_leak_check(srv)
    assert mid_cancels > 0
    assert srv.stats()["cancelled_requests"] >= mid_cancels


# ---------------------------------------------------------------------------
# deadlines: TIMED_OUT within one chunk sync, resources freed, cancel races
# ---------------------------------------------------------------------------


def test_deadline_times_out_midflight(qwen, qwen_params):
    """A running request whose deadline elapses terminates TIMED_OUT at the
    next chunk sync with partial output kept and every page freed; a
    co-batched request is untouched."""
    from repro.serving.server import DeadlineExceeded
    srv = LLMServer(qwen, num_slots=2, capacity=128, params=qwen_params,
                    engine_cfg=EngineConfig(cache_mode="paged",
                                            decode_chunk=2))
    h = srv.submit("deadline bounded request",
                   SamplingParams(max_new_tokens=64, deadline_s=30.0))
    survivor = srv.submit("co-batched survivor",
                          SamplingParams(max_new_tokens=48))
    while h.status().value != "running":
        srv.step()
    srv.step()
    h.request._submit_t -= 100.0          # push the submit past the deadline
    srv.step()                            # ... the next chunk sync notices
    assert h.done and h.status().value == "timed_out"
    assert h.status().terminal
    assert isinstance(h.exception(), DeadlineExceeded)
    assert h.result() == h.request.output_text   # partial output kept
    assert survivor.result() and survivor.status().value == "completed"
    assert srv.stats()["timed_out"] == 1
    eng = srv.engine
    assert all(s.request is None for s in eng.slots)
    owned = eng.radix.check_invariants()
    free = set(eng.kvpool._free)
    assert not (owned & free)
    assert len(owned) + len(free) == eng.kvpool.num_pages - eng.kvpool.reserved


def test_deadline_default_and_queued_expiry(qwen, qwen_params):
    """The server-level default deadline applies to every request that does
    not override it; a request can time out while still queued."""
    srv = LLMServer(qwen, num_slots=1, capacity=128, params=qwen_params,
                    default_deadline_s=1e-6)
    a = srv.submit("will expire", SamplingParams(max_new_tokens=8))
    b = srv.submit("will finish",
                   SamplingParams(max_new_tokens=8, deadline_s=300.0))
    a.result(), b.result()
    assert a.status().value == "timed_out"        # server default applied
    assert b.status().value == "completed"        # per-request override wins
    assert a.request.output_tokens == 0           # expired before admission


@given(st.lists(st.tuples(st.integers(0, 2), st.integers(2, 12),
                          st.integers(0, 3)),
                min_size=2, max_size=8))
@settings(max_examples=25, deadline=None)
def test_deadline_cancel_race_no_leak(ops):
    """Deadline expiry racing explicit cancel() (and normal completion)
    on the shared tiny-pool cancel server: whichever terminal state wins,
    every handle lands in exactly one of them and the exactly-once page
    ownership invariant holds after the drain."""
    srv = _cancel_server()
    handles = []
    for kind, budget, steps in ops:
        dl = (None, 1e-6, 0.02)[kind]
        handles.append(srv.submit(
            "err 429 err 429 err 429. tail %d" % (budget % 3),
            SamplingParams(max_new_tokens=budget, deadline_s=dl)))
        for _ in range(steps):
            srv.step()
        if kind == 2:
            srv.cancel(handles[-(1 + steps % len(handles))])
    srv.run_until_idle()
    for h in handles:
        assert h.status().terminal
        assert h.status().value in ("completed", "cancelled", "timed_out")
    _cancel_leak_check(srv)


# ---------------------------------------------------------------------------
# per-request RNG: seed-reproducible regardless of batch composition
# ---------------------------------------------------------------------------


def test_seed_reproducible_across_num_slots(qwen, qwen_params):
    """Same SamplingParams.seed -> same stochastic output at num_slots 1 vs
    4, and with or without co-batched neighbours: each request draws from
    its own fold_in(PRNGKey(seed), t) chain, never from a batch-shared
    stream."""
    sp = SamplingParams(max_new_tokens=10, temperature=0.9, top_k=8, seed=123)
    outs = []
    for slots in (1, 4):
        srv = LLMServer(qwen, num_slots=slots, capacity=96,
                        params=qwen_params)
        h = srv.submit("sample with a pinned seed", sp)
        outs.append(h.result())
    assert outs[0] == outs[1]
    # co-batched with three other (differently seeded) requests: unchanged
    srv = LLMServer(qwen, num_slots=4, capacity=96, params=qwen_params)
    h = srv.submit("sample with a pinned seed", sp)
    others = [srv.submit("noise neighbour %d" % i,
                         SamplingParams(max_new_tokens=10, temperature=1.3,
                                        seed=i))
              for i in range(3)]
    srv.run_until_idle()
    assert h.result() == outs[0]
    assert len({o.result() for o in others}) == 3   # distinct seeds, streams


# ---------------------------------------------------------------------------
# stop sequences
# ---------------------------------------------------------------------------


def test_stop_sequence_split_across_chunk_boundary(qwen, qwen_params):
    """A multi-token stop string whose pieces land in DIFFERENT decode
    chunks is still caught (the host-side check sees the whole decoded
    text), and tokens after the stop are trimmed from the result."""
    srv = LLMServer(qwen, num_slots=1, capacity=96, params=qwen_params,
                    engine_cfg=EngineConfig(decode_chunk=4))
    free_h = srv.submit("tell me something", SamplingParams(max_new_tokens=16))
    free_text = free_h.result()
    g = free_h.request.output_ids
    assert len(g) == 16
    dec = srv.engine.tokenizer.decode
    # a stop spanning output tokens 5..7: token 1 comes from prefill and
    # chunks are 4 tokens, so tokens 5/6 land in chunk 1 and token 7 in
    # chunk 2 — the stop is complete only after the SECOND chunk's sync
    stop = dec(g[:7])[len(dec(g[:4])):]
    assert stop and stop in free_text
    h2 = srv.submit("tell me something",
                    SamplingParams(max_new_tokens=16, stop=(stop,)))
    out = h2.result()
    assert stop in out                            # the stop itself is kept
    n = h2.request.output_tokens
    assert n < 16                                 # tokens after it trimmed
    assert h2.request.output_ids == g[:n]         # trim, not divergence
    # minimality at token granularity: one token fewer loses the stop
    assert stop not in dec(g[:n - 1])
    # a stop that never appears changes nothing
    h3 = srv.submit("tell me something",
                    SamplingParams(max_new_tokens=16, stop=("\x00unseen",)))
    assert h3.result() == free_text


# ---------------------------------------------------------------------------
# priority classes + deprecation shim
# ---------------------------------------------------------------------------


def test_priority_classes_admit_first(qwen, qwen_params):
    srv = LLMServer(qwen, num_slots=1, capacity=96, params=qwen_params)
    low = srv.submit("background batch job", SamplingParams(max_new_tokens=4))
    high = srv.submit("interactive user turn",
                      SamplingParams(max_new_tokens=4, priority=5))
    low2 = srv.submit("another batch job", SamplingParams(max_new_tokens=4))
    srv.run_until_idle()
    assert high.request.admit_index < low.request.admit_index
    assert low.request.admit_index < low2.request.admit_index  # FIFO in class


def test_deprecated_submit_shim_still_serves(qwen, qwen_params):
    """The ONE test keeping the old kwargs path covered: ServingEngine
    .submit/.generate warn but still produce the LLMServer output."""
    eng = ServingEngine(qwen, num_slots=2, capacity=96, params=qwen_params)
    with pytest.warns(DeprecationWarning):
        req = eng.submit("legacy caller", max_new_tokens=6)
    eng.run_until_drained()
    srv = LLMServer(qwen, num_slots=2, capacity=96, params=qwen_params)
    assert srv.submit("legacy caller",
                      SamplingParams(max_new_tokens=6)).result() \
        == req.output_text
