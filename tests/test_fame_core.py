"""FAME core unit tests: FaaS platform, workflow, memory, cache, wrapper,
fusion — the paper's §3 mechanisms."""
import json

import pytest

from repro.core.config import CONFIGS
from repro.core.faas import FaaSPlatform, FaaSTimeout, FunctionDef
from repro.core.fusion import plan_consolidated, plan_singleton
from repro.core.kvstore import KVStore
from repro.core.memory import AgentMemory, MemoryEntry
from repro.core.mcp import FastMCP, rpc_call, rpc_tools_list
from repro.core.objectstore import ObjectStore
from repro.core.telemetry import Trace, use_trace
from repro.core.toolcache import CacheManager
from repro.core.workflow import (ChoiceState, FailState, StateMachine,
                                 SucceedState, TaskState, build_react_machine)
from repro.core.wrapper import parse_server_source, wrap_server


# ---------------------------------------------------------------------------
# FaaS platform
# ---------------------------------------------------------------------------


def _echo(payload, ctx):
    ctx.charge(payload.get("work_s", 0.1))
    return dict(payload, handled=True)


def test_cold_start_then_warm():
    p = FaaSPlatform()
    p.deploy(FunctionDef("f", _echo, cold_start_s=2.0))
    _, t1 = p.invoke("f", {"work_s": 0.5}, 0.0)
    assert t1 == pytest.approx(2.5)              # cold start + work
    _, t2 = p.invoke("f", {"work_s": 0.5}, t1)
    assert t2 == pytest.approx(t1 + 0.5)         # warm
    assert p.stats["f"]["cold_starts"] == 1
    assert p.stats["f"]["invocations"] == 2


def test_retention_reclaim_causes_new_cold_start():
    p = FaaSPlatform()
    p.deploy(FunctionDef("f", _echo, cold_start_s=1.0, retention_s=60.0))
    _, t1 = p.invoke("f", {}, 0.0)
    p.invoke("f", {}, t1 + 120.0)                # past retention
    assert p.stats["f"]["cold_starts"] == 2


def test_concurrent_invocations_scale_instances():
    p = FaaSPlatform()
    p.deploy(FunctionDef("f", _echo, cold_start_s=1.0))
    p.invoke("f", {"work_s": 10.0}, 0.0)         # occupies instance until 11
    p.invoke("f", {"work_s": 10.0}, 1.0)         # needs a second instance
    assert p.stats["f"]["cold_starts"] == 2
    assert len(p.instances["f"]) == 2


def test_timeout_enforced():
    p = FaaSPlatform()
    p.deploy(FunctionDef("f", _echo, timeout_s=5.0))
    with pytest.raises(FaaSTimeout):
        p.invoke("f", {"work_s": 10.0}, 0.0)


def test_billing_gb_seconds():
    p = FaaSPlatform()
    p.deploy(FunctionDef("f", _echo, memory_mb=1024, cold_start_s=0.0))
    p.invoke("f", {"work_s": 2.0}, 0.0)
    assert p.stats["f"]["gb_s"] == pytest.approx(2.0)


def test_platform_retry_on_injected_failure():
    p = FaaSPlatform()
    p.deploy(FunctionDef("f", _echo))
    p.inject_failures("f", 1)
    res, _ = p.invoke("f", {}, 0.0)
    assert res["handled"]
    assert p.stats["f"]["errors"] == 1


def test_straggler_speculative_execution():
    p = FaaSPlatform(straggler_deadline_s=1.0)
    p.deploy(FunctionDef("f", _echo, cold_start_s=0.0))
    res, t_end = p.invoke("f", {"work_s": 5.0}, 0.0)
    assert p.stats["f"]["speculative_retries"] == 1
    assert res["handled"]


# ---------------------------------------------------------------------------
# Workflow (Step Functions)
# ---------------------------------------------------------------------------


def test_react_machine_cycles_until_success():
    p = FaaSPlatform()
    attempts = []

    def planner(payload, ctx):
        return dict(payload, plan="p")

    def actor(payload, ctx):
        attempts.append(1)
        return dict(payload, result=len(attempts))

    def evaluator(payload, ctx):
        ok = payload["result"] >= 2
        return dict(payload, verdict={"success": ok, "needs_retry": not ok})

    for name, h in [("P", planner), ("A", actor), ("E", evaluator)]:
        p.deploy(FunctionDef(name, h))
    m = build_react_machine(p, planner_fn="P", actor_fn="A", evaluator_fn="E",
                            max_iterations=3)
    payload, t, status = m.execute({"iteration": 1}, 0.0)
    assert status == "SUCCEEDED"
    assert len(attempts) == 2                      # one retry cycle


def test_react_machine_fails_after_max_iterations():
    p = FaaSPlatform()
    for name in ("P", "A"):
        p.deploy(FunctionDef(name, lambda pl, ctx: pl))
    p.deploy(FunctionDef("E", lambda pl, ctx: dict(
        pl, verdict={"success": False, "needs_retry": True})))
    m = build_react_machine(p, planner_fn="P", actor_fn="A", evaluator_fn="E",
                            max_iterations=3)
    _, _, status = m.execute({"iteration": 1}, 0.0)
    assert status == "FAILED"


def test_task_retry_then_dlq():
    p = FaaSPlatform()
    p.deploy(FunctionDef("boom", lambda pl, ctx: 1 / 0))
    m = StateMachine("m", p, [TaskState("T", "boom", next="Done"),
                              SucceedState("Done"), FailState()], "T")
    _, _, status = m.execute({}, 0.0)
    assert status == "FAILED"                       # retries exhausted → DLQ


# ---------------------------------------------------------------------------
# Memory (§3.2)
# ---------------------------------------------------------------------------


def test_memory_persist_and_inject_order():
    mem = AgentMemory(KVStore())
    for i in range(3):
        mem.persist(MemoryEntry("s1", f"inv{i}", f"q{i}",
                                [{"role": "tool", "tool": "t",
                                  "arguments": {"x": i}, "content": f"r{i}"}],
                                f"resp{i}"))
    mem.persist(MemoryEntry("s2", "invX", "other", [], "respX"))
    ctx = mem.render_context("s1")
    assert "r0" in ctx and "r2" in ctx and "respX" not in ctx
    assert ctx.index("r0") < ctx.index("r1") < ctx.index("r2")
    assert "[ToolMessage tool=t" in ctx


def test_memory_disabled_is_empty():
    mem = AgentMemory(KVStore(), enabled=False)
    mem.persist(MemoryEntry("s", "i", "q", [], "r"))
    assert mem.render_context("s") == ""


# ---------------------------------------------------------------------------
# Object store + cache (§3.3.2)
# ---------------------------------------------------------------------------


def test_objectstore_ttl_staleness():
    store = ObjectStore()
    store.put("b", "k", b"data", {"ttl_s": 10}, t=100.0)
    assert store.get("b", "k", t=105.0) is not None
    assert store.get("b", "k", t=111.0) is None      # stale


def test_cache_hit_miss_and_ttl_zero():
    store = ObjectStore()
    cache = CacheManager(store)
    hit, _ = cache.lookup("tool", {"a": 1}, ttl_s=-1, t=0.0)
    assert not hit
    cache.put("tool", {"a": 1}, {"out": 42}, ttl_s=-1, t=0.0)
    hit, val = cache.lookup("tool", {"a": 1}, ttl_s=-1, t=1000.0)
    assert hit and val == {"out": 42}
    # ttl 0 disables caching entirely
    cache.put("t2", {}, {"x": 1}, ttl_s=0, t=0.0)
    hit, _ = cache.lookup("t2", {}, ttl_s=0, t=0.0)
    assert not hit
    # different args -> different key
    hit, _ = cache.lookup("tool", {"a": 2}, ttl_s=-1, t=0.0)
    assert not hit


# ---------------------------------------------------------------------------
# Wrapper automation (§3.3.1)
# ---------------------------------------------------------------------------

SAMPLE_SOURCE = '''
import os
from repro.core.mcp import FastMCP

mcp = FastMCP("sample")
API = "https://example.com"

@mcp.tool(description="fetch a url")
@fame.wrapper()
def fetch(url: str, max_length: int = 5000):
    return url

@mcp.tool()
@fame.wrapper()
async def fetch_async(url: str):
    return url

def helper(x):
    return x
'''


def test_ast_parse_detects_tools_and_helpers():
    parsed = parse_server_source(SAMPLE_SOURCE)
    assert parsed.tool_names == ["fetch", "fetch_async"]
    assert parsed.async_tools == ["fetch_async"]
    assert "helper" in parsed.helper_functions
    assert parsed.server_var == "mcp"
    assert any("import os" in i for i in parsed.imports)
    assert any("API" in c for c in parsed.constants)


def test_wrap_server_generates_handler_and_serves_rpc():
    server = FastMCP("sample")

    @server.tool(description="fetch a url")
    def fetch(url: str, max_length: int = 5000):
        return f"fetched {url}"

    @server.tool()
    async def fetch_async(url: str):
        return f"async {url}"

    w = wrap_server(server, source=None)
    assert "lambda_handler" in w.wrapper_source
    p = FaaSPlatform()
    p.deploy(w.function_def())
    resp, _ = p.invoke("mcp-sample", {"body": rpc_tools_list()}, 0.0)
    tools = [t["name"] for t in resp["body"]["result"]["tools"]]
    assert tools == ["fetch", "fetch_async"]
    resp, _ = p.invoke("mcp-sample",
                       {"body": rpc_call("fetch", {"url": "http://x"})}, 0.0)
    assert "fetched http://x" in resp["body"]["result"]["content"][0]["text"]
    resp, _ = p.invoke("mcp-sample",
                       {"body": rpc_call("fetch_async", {"url": "y"})}, 0.0)
    assert "async y" in resp["body"]["result"]["content"][0]["text"]


def test_wrap_server_source_mismatch_raises():
    server = FastMCP("sample")

    @server.tool()
    def fetch(url: str):
        return url

    with pytest.raises(ValueError):
        wrap_server(server, source=SAMPLE_SOURCE)   # fetch_async missing


# ---------------------------------------------------------------------------
# Fusion (§3.3.2 / §5.3.2)
# ---------------------------------------------------------------------------


def _two_servers():
    a, b = FastMCP("a", memory_mb=128), FastMCP("b", memory_mb=400)

    @a.tool()
    def t_a(x: int):
        return x + 1

    @b.tool()
    def t_b(x: int):
        return x * 2

    return [wrap_server(a), wrap_server(b)]


def test_singleton_vs_consolidated_memory_and_cold_starts():
    singles = plan_singleton(_two_servers())
    consol = plan_consolidated(_two_servers(), "fused")
    assert len(singles.functions) == 2
    assert len(consol.functions) == 1
    assert consol.functions[0].memory_mb == 400      # max of constituents
    # consolidated: ONE cold start serves both tools
    p = FaaSPlatform()
    for fn in consol.functions:
        p.deploy(fn)
    p.invoke("fused", {"body": rpc_call("t_a", {"x": 1})}, 0.0)
    p.invoke("fused", {"body": rpc_call("t_b", {"x": 2})}, 10.0)
    assert p.stats["fused"]["cold_starts"] == 1
    # singleton: one per server
    p2 = FaaSPlatform()
    for fn in singles.functions:
        p2.deploy(fn)
    p2.invoke(singles.tool_to_function["t_a"], {"body": rpc_call("t_a", {"x": 1})}, 0.0)
    p2.invoke(singles.tool_to_function["t_b"], {"body": rpc_call("t_b", {"x": 2})}, 10.0)
    assert sum(p2.stats[f.name]["cold_starts"] for f in singles.functions) == 2
