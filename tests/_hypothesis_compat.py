"""Graceful fallback when ``hypothesis`` isn't installed.

Test modules import ``given``/``settings``/``st`` from here instead of from
``hypothesis`` directly. With hypothesis present this is a pure re-export;
without it, property-based tests become individually-skipped tests instead of
aborting collection of the whole module (which used to take every non-property
test in the file down with it).
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        def deco(fn):
            def skipped():
                pytest.skip("hypothesis not installed")
            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            return skipped
        return deco

    def settings(*_a, **_k):
        def deco(fn):
            return fn
        return deco

    class _Strategy:
        """Inert stand-in: strategy expressions build but never run."""

        def __call__(self, *a, **k):
            return self

        def __getattr__(self, name):
            return self

    st = _Strategy()
