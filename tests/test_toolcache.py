"""Tool-result cache (§3.3.2): key canonicalization and TTL boundary
semantics.  The canonical rendering is load-bearing beyond hashing — the
serving layer (fame/toolflow.py) re-injects cached results token-identically
from ``canonical_args_text``, so equal-by-value args MUST serialize equal and
non-JSON args MUST fail loudly rather than collide via ``str()`` reprs."""
import math

import pytest

from repro.core.objectstore import ObjectStore
from repro.core.toolcache import (CacheManager, cache_key, canonical_args_text,
                                  canonicalize)


# ---- canonicalization -----------------------------------------------------

def test_canonicalize_json_scalars_pass_through():
    assert canonicalize(None) is None
    assert canonicalize(True) is True
    assert canonicalize(3) == 3
    assert canonicalize(2.5) == 2.5
    assert canonicalize("x") == "x"


def test_canonicalize_tuple_list_equivalence():
    assert canonicalize((1, 2, ("a",))) == [1, 2, ["a"]]
    assert (cache_key("t", {"xs": (1, 2)}) == cache_key("t", {"xs": [1, 2]}))


def test_canonical_args_text_key_order_invariant():
    assert (canonical_args_text({"b": 1, "a": {"d": 2, "c": 3}})
            == canonical_args_text({"a": {"c": 3, "d": 2}, "b": 1}))
    # compact separators: no whitespace drift between producer and re-injector
    assert canonical_args_text({"a": [1, 2]}) == '{"a":[1,2]}'


def test_canonicalize_rejects_non_json_types_with_path():
    class Query:
        def __repr__(self):
            return "q"

    with pytest.raises(TypeError, match=r"args\.q has non-JSON type Query"):
        canonicalize({"q": Query()})
    with pytest.raises(TypeError, match=r"args\[1\] has non-JSON type set"):
        canonicalize([1, {2}])
    with pytest.raises(TypeError, match="non-string dict key"):
        canonicalize({"a": {1: "x"}})
    with pytest.raises(TypeError, match="non-finite float"):
        canonicalize({"x": math.inf})
    with pytest.raises(TypeError, match="non-finite float"):
        canonicalize([math.nan])


def test_no_str_repr_collisions():
    # two distinct objects with equal reprs must not silently share a key
    class A:
        def __repr__(self):
            return "same"

    class B:
        def __repr__(self):
            return "same"

    for bad in (A(), B()):
        with pytest.raises(TypeError):
            cache_key("tool", {"arg": bad})
    # and genuinely different JSON values never collide
    assert cache_key("t", {"a": "1"}) != cache_key("t", {"a": 1})
    assert cache_key("t", {"a": True}) != cache_key("t", {"a": 1})


# ---- TTL boundaries -------------------------------------------------------

def test_ttl_exactly_at_boundary_is_fresh():
    # staleness is strict (now - put > ttl): an entry aged EXACTLY ttl_s
    # seconds is still served; one tick past is not.
    cache = CacheManager(ObjectStore())
    cache.put("tool", {"a": 1}, {"out": 1}, ttl_s=10.0, t=100.0)
    hit, val = cache.lookup("tool", {"a": 1}, ttl_s=10.0, t=110.0)
    assert hit and val == {"out": 1}
    hit, _ = cache.lookup("tool", {"a": 1}, ttl_s=10.0, t=110.0 + 1e-6)
    assert not hit
    assert (cache.hits, cache.misses) == (1, 1)


def test_ttl_minus_one_is_infinite_not_instant():
    cache = CacheManager(ObjectStore())
    cache.put("doi", {"id": "x"}, "pdf", ttl_s=-1, t=0.0)
    hit, val = cache.lookup("doi", {"id": "x"}, ttl_s=-1, t=1e9)
    assert hit and val == "pdf"


def test_ttl_zero_never_caches_either_side():
    # ttl_s=0 short-circuits both put and lookup — nothing is stored, and a
    # lookup with ttl_s=0 misses even if an entry exists under another ttl.
    cache = CacheManager(ObjectStore())
    cache.put("quote", {"sym": "ACME"}, 99, ttl_s=0, t=0.0)
    assert cache.store.list("fame-mcp-cache") == []
    cache.put("quote", {"sym": "ACME"}, 99, ttl_s=-1, t=0.0)
    hit, _ = cache.lookup("quote", {"sym": "ACME"}, ttl_s=0, t=0.0)
    assert not hit and cache.misses == 0      # short-circuit: not even a miss


def test_disabled_cache_is_inert():
    cache = CacheManager(ObjectStore(), enabled=False)
    cache.put("t", {}, 1, ttl_s=-1, t=0.0)
    hit, _ = cache.lookup("t", {}, ttl_s=-1, t=0.0)
    assert not hit and cache.store.list("fame-mcp-cache") == []
