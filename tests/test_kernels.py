"""Pallas kernel validation: shape/dtype sweeps vs the kernels/ref.py oracles
(interpret=True executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mlstm_chunk import mlstm_chunk
from repro.kernels.rglru_scan import rglru_scan

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,S,T,H,hd", [
    (1, 64, 64, 1, 32), (2, 100, 100, 3, 32), (1, 33, 129, 2, 64),
    (2, 256, 256, 2, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [None, 17])
def test_flash_attention_sweep(B, S, T, H, hd, dtype, window):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(ks[0], (B, S, H, hd), dtype)
    k = _rand(ks[1], (B, T, H, hd), dtype)
    v = _rand(ks[2], (B, T, H, hd), dtype)
    got = flash_attention(q, k, v, window=window, block_q=32, block_kv=32)
    want = ref.attention(q.astype(jnp.float32), k.astype(jnp.float32),
                         v.astype(jnp.float32), window=window)
    assert jnp.max(jnp.abs(got.astype(jnp.float32) - want)) < TOL[dtype]


def test_flash_attention_matches_model_xla_path():
    from repro.models.attention import flash_attention as xla_flash
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = _rand(ks[0], (2, 80, 4, 32), jnp.float32)
    k = _rand(ks[1], (2, 80, 4, 32), jnp.float32)
    v = _rand(ks[2], (2, 80, 4, 32), jnp.float32)
    got = flash_attention(q, k, v, block_q=32, block_kv=32)
    xla = xla_flash(q, k, v, block_q=32, block_kv=32)
    assert jnp.max(jnp.abs(got - xla)) < 2e-5


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,W,K,G,hd", [
    (1, 64, 1, 1, 32), (3, 200, 2, 4, 32), (2, 128, 4, 2, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("vector_clen", [False, True])
def test_decode_attention_sweep(B, W, K, G, hd, dtype, vector_clen):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = _rand(ks[0], (B, 1, K * G, hd), dtype)
    kc = _rand(ks[1], (B, W, K, hd), dtype)
    vc = _rand(ks[2], (B, W, K, hd), dtype)
    clen = (jnp.arange(B, dtype=jnp.int32) * (W // max(B, 1)) + W // 2 - 1
            if vector_clen else jnp.array(W - 1, jnp.int32))
    got = decode_attention(q, kc, vc, clen, q_per_kv=G, block_w=64)
    want = ref.decode_attention(q.astype(jnp.float32), kc.astype(jnp.float32),
                                vc.astype(jnp.float32), clen, q_per_kv=G)
    assert jnp.max(jnp.abs(got.astype(jnp.float32) - want)) < TOL[dtype]


def test_decode_attention_window_ring():
    B, W, K, G, hd = 2, 64, 2, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = _rand(ks[0], (B, 1, K * G, hd), jnp.float32)
    kc = _rand(ks[1], (B, W, K, hd), jnp.float32)
    vc = _rand(ks[2], (B, W, K, hd), jnp.float32)
    clen = jnp.array([70, 200], jnp.int32)       # wrapped ring
    got = decode_attention(q, kc, vc, clen, q_per_kv=G, window=24, block_w=32)
    want = ref.decode_attention(q, kc, vc, clen, q_per_kv=G, window=24)
    assert jnp.max(jnp.abs(got - want)) < 2e-5


# ---------------------------------------------------------------------------
# RG-LRU scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,S,R", [(1, 64, 64), (2, 150, 100), (1, 257, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rglru_scan_sweep(B, S, R, dtype):
    a = jax.nn.sigmoid(_rand(jax.random.PRNGKey(4), (B, S, R), jnp.float32))
    bx = _rand(jax.random.PRNGKey(5), (B, S, R), jnp.float32)
    got, h = rglru_scan(a.astype(dtype), bx.astype(dtype), block_t=32, block_r=64)
    want, hw = ref.rglru_scan(a, bx)
    tol = 5e-6 if dtype == jnp.float32 else 5e-2
    assert jnp.max(jnp.abs(got.astype(jnp.float32) - want)) < tol


def test_rglru_kernel_matches_associative_scan_path():
    """The model's associative-scan path == the kernel's sequential path."""
    from repro.models.rglru import rglru_scan as assoc_path
    a = jax.nn.sigmoid(jax.random.normal(jax.random.PRNGKey(6), (2, 96, 64)))
    bx = jax.random.normal(jax.random.PRNGKey(7), (2, 96, 64))
    got, _ = rglru_scan(a, bx, block_t=32, block_r=64)

    def op(l, r):
        (al, bl), (ar, br) = l, r
        return al * ar, ar * bl + br
    _, want = jax.lax.associative_scan(op, (a, bx), axis=1), None
    aa, hh = jax.lax.associative_scan(op, (a, bx), axis=1)
    assert jnp.max(jnp.abs(got - hh)) < 1e-4


# ---------------------------------------------------------------------------
# mLSTM chunkwise
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,S,H,hd,chunk", [
    (1, 32, 1, 32, 8), (2, 96, 2, 32, 32), (1, 100, 2, 64, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mlstm_chunk_sweep(B, S, H, hd, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(8), 5)
    q = _rand(ks[0], (B, S, H, hd), dtype) * 0.5
    k = _rand(ks[1], (B, S, H, hd), dtype) * 0.5
    v = _rand(ks[2], (B, S, H, hd), dtype)
    ig = _rand(ks[3], (B, S, H), jnp.float32)
    fg = _rand(ks[4], (B, S, H), jnp.float32) + 2.0
    got = mlstm_chunk(q, k, v, ig, fg, chunk=chunk)
    want, _ = ref.mlstm(q.astype(jnp.float32), k.astype(jnp.float32),
                        v.astype(jnp.float32), ig, fg)
    tol = 2e-5 if dtype == jnp.float32 else 5e-2
    assert jnp.max(jnp.abs(got.astype(jnp.float32) - want)) < tol


# ---------------------------------------------------------------------------
# property-based: invariants under random shapes
# ---------------------------------------------------------------------------


@settings(deadline=None, max_examples=12)
@given(S=st.integers(8, 80), hd=st.sampled_from([16, 32]),
       window=st.one_of(st.none(), st.integers(4, 40)))
def test_flash_attention_property(S, hd, window):
    ks = jax.random.split(jax.random.PRNGKey(S * 31 + hd), 3)
    q = _rand(ks[0], (1, S, 2, hd), jnp.float32)
    k = _rand(ks[1], (1, S, 2, hd), jnp.float32)
    v = _rand(ks[2], (1, S, 2, hd), jnp.float32)
    got = flash_attention(q, k, v, window=window, block_q=16, block_kv=16)
    want = ref.attention(q, k, v, window=window)
    assert jnp.max(jnp.abs(got - want)) < 3e-5


@settings(deadline=None, max_examples=10)
@given(S=st.integers(4, 64), chunk=st.sampled_from([4, 8, 16]))
def test_mlstm_chunk_invariant_to_chunk_size(S, chunk):
    """Chunk size is a tiling choice — results must not depend on it."""
    ks = jax.random.split(jax.random.PRNGKey(S), 5)
    q = _rand(ks[0], (1, S, 1, 16), jnp.float32)
    k = _rand(ks[1], (1, S, 1, 16), jnp.float32)
    v = _rand(ks[2], (1, S, 1, 16), jnp.float32)
    ig = _rand(ks[3], (1, S, 1), jnp.float32)
    fg = _rand(ks[4], (1, S, 1), jnp.float32) + 1.0
    a = mlstm_chunk(q, k, v, ig, fg, chunk=chunk)
    b = mlstm_chunk(q, k, v, ig, fg, chunk=S)
    assert jnp.max(jnp.abs(a - b)) < 2e-5


# ---------------------------------------------------------------------------
# use_pallas routing: the kernel path must equal the XLA path END TO END
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["qwen2.5-3b", "mixtral-8x22b",
                                  "recurrentgemma-9b", "xlstm-350m"])
def test_use_pallas_model_parity(name):
    import dataclasses
    from repro.configs.registry import ARCHS
    from repro.models import Model
    from repro.models import transformer as tfm
    cfg = ARCHS[name].reduced(dtype="float32", param_dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab_size)
    batch = model.make_batch(tok)
    ref_logits, _, _ = tfm.forward_logits(params, batch, cfg, mode="train")
    cfg_k = dataclasses.replace(cfg, use_pallas=True)
    got_logits, _, _ = tfm.forward_logits(params, batch, cfg_k, mode="train")
    assert float(jnp.max(jnp.abs(got_logits - ref_logits))) < 3e-3
