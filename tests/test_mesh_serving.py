"""Mesh-sharded serving: the multi-device bit-identity lane (ISSUE 9).

Greedy decoding through ``EngineConfig(mesh=...)`` on a 2×4 host mesh must be
**bit-identical** to the single-device server — not merely close.  The serve
layout earns this by never splitting a float contraction across devices
(distributed/sharding.py ``_serve_rules``): batch-like dims shard, reduction
dims replicate, and the pre-down-projection all-gathers move bits without
re-associating sums.  These tests are the enforcement: every cache mode ×
spec-decode setting × arch family runs the same prompts on one device and on
the mesh with identical params and seeds, and compares final strings
outright.  On top of the matrix: preemption must resume bit-identically on
the mesh, session tails must still hit, and racing client threads against a
pumping *sharded* server must preserve exactly-once page / snapshot
ownership (the host-side allocators never learn the pool rows now live on
eight devices).

The whole module skips unless the process sees >= 8 devices — CI's ``mesh``
job provides them via ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(tier-1 collects this file and skips it, keeping the default lane fast).
"""
import threading

import jax
import pytest

from repro.configs.registry import ARCHS
from repro.launch.mesh import make_test_mesh
from repro.serving.faults import OverloadError
from repro.serving.scheduler import OverloadPolicy
from repro.serving.server import (EngineConfig, LLMServer, SamplingParams)

from tests._hypothesis_compat import given, settings, st

pytestmark = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="mesh lane needs 8 devices: run under "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8")


@pytest.fixture(scope="module")
def mesh():
    return make_test_mesh((2, 4))


def _cfg(arch, **over):
    """Tiny f32 config; qwen bumps KV heads to 4 so the pool's KV-head dim
    genuinely shards over the 4-way "model" axis (the reduced default of 2
    would fall back to replicated on that dim)."""
    if arch == "qwen2.5-3b":
        over.setdefault("num_kv_heads", 4)
    return ARCHS[arch].reduced(dtype="float32", param_dtype="float32",
                               vocab_size=512, **over)


PROMPTS = ["the quick brown fox", "the quick brown dog jumps over",
           "err 429 err 429 err 429. go"]


def _run(cfg, ecfg, params=None, seed=7, max_new=12):
    srv = LLMServer(cfg, num_slots=2, capacity=96, seed=seed, params=params,
                    engine_cfg=ecfg)
    hs = [srv.submit(p, SamplingParams(max_new_tokens=max_new))
          for p in PROMPTS]
    srv.run_until_idle()
    outs = [h.result() for h in hs]
    stats, params = srv.stats(), srv.params
    srv.close()
    return outs, params, stats


# ---------------------------------------------------------------------------
# the matrix: cache mode × speculative decode × arch family
# ---------------------------------------------------------------------------
# "paged" on recurrentgemma resolves to the snapshot arena (stateful arch),
# so the three cache substrates — dense rows, KV page pool, state snapshots —
# are all covered.  mixtral exercises expert-parallel MoE on the mesh.
_CELLS = [(a, m, s)
          for a in ("qwen2.5-3b", "recurrentgemma-9b", "mixtral-8x22b")
          for m in ("dense", "paged")
          for s in (0, 4)]


@pytest.mark.parametrize("arch,mode,spec", _CELLS,
                         ids=[f"{a.split('-')[0]}-{m}-spec{s}"
                              for a, m, s in _CELLS])
def test_bit_identical_across_mesh(mesh, arch, mode, spec):
    cfg = _cfg(arch)
    kw = dict(cache_mode=mode, page_size=8, spec_len=spec)
    ref, params, ref_stats = _run(cfg, EngineConfig(**kw))
    assert not ref_stats["sharded"]
    got, _, stats = _run(cfg, EngineConfig(mesh=mesh, **kw),
                         params=jax.device_get(params))
    assert stats["sharded"] and stats["mesh_devices"] == 8
    assert stats["mesh_shape"] == {"data": 2, "model": 4}
    assert got == ref, (
        f"{arch}/{mode}/spec={spec}: mesh output diverged from single-device")


def test_pool_rows_round_up_to_data_axis(mesh):
    """AUTO-sized pools round their row count up to a multiple of the data
    axis so device_put accepts the sharding (explicit sizes are respected
    and just fall back to replicated rows when they don't divide)."""
    srv = LLMServer(_cfg("qwen2.5-3b"), num_slots=3, capacity=40,
                    engine_cfg=EngineConfig(cache_mode="paged", page_size=8,
                                            mesh=mesh))
    try:
        assert srv.engine.kvpool.num_pages % mesh.shape["data"] == 0
    finally:
        srv.close()


# ---------------------------------------------------------------------------
# preemption resumes bit-identically on the mesh; session tails still hit
# ---------------------------------------------------------------------------

def test_preempt_resume_bit_identical_on_mesh(mesh):
    cfg = _cfg("qwen2.5-3b")
    kw = dict(cache_mode="paged", page_size=8, decode_chunk=2)
    ref_srv = LLMServer(cfg, num_slots=1, capacity=128, seed=7,
                        engine_cfg=EngineConfig(**kw))
    r = ref_srv.submit("a long low priority ramble ",
                       SamplingParams(max_new_tokens=24))
    ref_srv.run_until_idle()
    ref_out, params = r.result(), jax.device_get(ref_srv.params)
    ref_srv.close()

    srv = LLMServer(cfg, num_slots=1, capacity=128, seed=7, params=params,
                    engine_cfg=EngineConfig(mesh=mesh, **kw),
                    overload=OverloadPolicy(preempt=True))
    with srv:
        lo = srv.submit("a long low priority ramble ",
                        SamplingParams(max_new_tokens=24))
        while lo.status().value != "running":
            srv.step()
        srv.step()
        hi = srv.submit("urgent", SamplingParams(max_new_tokens=8,
                                                 priority=5))
        srv.run_until_idle()
        assert hi.status().value == "completed"
        assert lo.request.preempted >= 1, "preemption never triggered"
        assert lo.result() == ref_out


def test_session_tail_reuse_on_mesh(mesh):
    srv = LLMServer(_cfg("qwen2.5-3b"), num_slots=1, capacity=128, seed=7,
                    engine_cfg=EngineConfig(cache_mode="paged", page_size=8,
                                            mesh=mesh))
    with srv:
        sess = srv.open_session()
        h1 = sess.submit("turn one: hello", SamplingParams(max_new_tokens=8))
        srv.run_until_idle()
        t1 = h1.result()
        h2 = sess.submit("turn one: hello" + t1 + " and more",
                         SamplingParams(max_new_tokens=8))
        srv.run_until_idle()
        assert h2.status().value == "completed"
        assert srv.stats()["turn_prefix_hits"] >= 1


# ---------------------------------------------------------------------------
# exactly-once ownership under racing clients, with sharded pools
# ---------------------------------------------------------------------------

_LOAD_SRV = None


def _load_server():
    """One lazily-built pumping server on the mesh, shared across hypothesis
    examples (the partitioned compiles are the expensive part)."""
    global _LOAD_SRV
    if _LOAD_SRV is None:
        _LOAD_SRV = LLMServer(
            _cfg("qwen2.5-3b"), num_slots=2, capacity=64,
            engine_cfg=EngineConfig(cache_mode="paged", page_size=8,
                                    num_pages=18, spec_len=4, decode_chunk=2,
                                    mesh=make_test_mesh((2, 4))),
            overload=OverloadPolicy(max_queue_depth=4, preempt=True),
            pump=True)
    return _LOAD_SRV


def _run_threaded_ops(ops):
    """test_overload's ownership harness pointed at the sharded server: after
    racing submit / cancel / priority ops drain, every page is owned exactly
    once (free list xor radix tree) even though the rows live on 8 devices —
    sharding must be invisible to the host-side allocator."""
    srv = _load_server()
    pool = ["err 429 err 429 err 429. " + t for t in
            ("", "tail one", "go go go go go", "a longer tail that repeats")]
    handles, lock = [], threading.Lock()

    def client(shard):
        for kind, variant, budget in shard:
            try:
                if kind == 0:
                    h = srv.submit(pool[variant],
                                   SamplingParams(max_new_tokens=budget))
                elif kind == 1:
                    h = srv.submit(pool[variant],
                                   SamplingParams(max_new_tokens=budget,
                                                  priority=2))
                else:
                    h = srv.submit(pool[variant],
                                   SamplingParams(max_new_tokens=budget))
                    srv.cancel(h)
            except OverloadError:
                continue
            with lock:
                handles.append(h)

    shards = [[op[1:] for op in ops if op[0] == t] for t in range(3)]
    threads = [threading.Thread(target=client, args=(s,)) for s in shards]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    srv.run_until_idle()
    assert all(h.request.finished for h in handles)
    eng = srv.engine
    assert not eng._queue and all(s.request is None for s in eng.slots)
    owned = eng.radix.check_invariants()
    free = set(eng.kvpool._free)
    assert not (owned & free)
    assert len(owned) + len(free) == eng.kvpool.num_pages - eng.kvpool.reserved


@given(st.lists(st.tuples(st.integers(0, 2),      # client thread
                          st.integers(0, 2),      # op kind
                          st.integers(0, 3),      # prompt variant
                          st.integers(2, 12)),    # token budget
                min_size=4, max_size=10))
@settings(max_examples=10, deadline=None)
def test_threaded_ownership_on_mesh(ops):
    _run_threaded_ops(ops)


def test_threaded_ownership_on_mesh_fixed_script():
    """Deterministic stand-in when hypothesis is unavailable."""
    _run_threaded_ops([(t, k, (t + k) % 4, 3 + 2 * k)
                       for t in range(3) for k in range(3)])


def test_threaded_snapshot_ownership_on_mesh(mesh):
    """Snapshot-arena twin on a stateful arch with sharded arena rows."""
    srv = LLMServer(
        _cfg("recurrentgemma-9b"), num_slots=2, capacity=64,
        engine_cfg=EngineConfig(cache_mode="paged", decode_chunk=2,
                                mesh=mesh),
        overload=OverloadPolicy(max_queue_depth=4, preempt=True),
        pump=True)
    with srv:
        def client(i):
            for j in range(3):
                try:
                    h = srv.submit(f"stateful {i} turn {j} " * 2,
                                   SamplingParams(max_new_tokens=6,
                                                  priority=j % 2))
                except OverloadError:
                    continue
                if (i + j) % 3 == 0:
                    srv.cancel(h)
        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(3)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        srv.run_until_idle()
        eng = srv.engine
        assert not eng._queue and all(s.request is None for s in eng.slots)
        owned = eng.radix.check_invariants(snapshots=True)
        free = set(eng.snaps._free)
        assert not (owned & free)
        assert len(owned) + len(free) == eng.snaps.num_snaps
