"""Overload control (OverloadPolicy on the Scheduler): bounded admission,
load shedding, the dispatch circuit breaker, and priority preemption.

Acceptance invariants (ISSUE 8): refusals and sheds are TYPED
(``OverloadError`` / ``ShedError``), never silent drops or stranded
handles; preemption happens only for strictly higher priority and the
preempted request resumes **bit-identically** (same tokens, same RNG
chain) across dense / paged / snapshot cache modes; and random
multi-threaded submit / cancel / preempt interleavings against a pumping
server preserve exactly-once page ownership.
"""
import threading
import time

import pytest

from repro.configs.registry import ARCHS
from repro.serving.faults import OverloadError, ShedError
from repro.serving.scheduler import OverloadPolicy
from repro.serving.server import (EngineConfig, FaultInjector, LLMServer,
                                  RetryPolicy, SamplingParams)

from tests._hypothesis_compat import given, settings, st


def _cfg(arch):
    return ARCHS[arch].reduced(dtype="float32", param_dtype="float32",
                               vocab_size=512)


def _sp(max_new=8, priority=0, **kw):
    return SamplingParams(max_new_tokens=max_new, priority=priority, **kw)


# ---------------------------------------------------------------------------
# bounded admission: depth caps, displacement, age caps, predictive shed
# ---------------------------------------------------------------------------

def test_queue_depth_cap_and_priority_displacement():
    """A full admission queue refuses equal-or-lower arrivals typed, but a
    HIGHER-priority arrival displaces the youngest lower-priority queued
    request (typed ShedError on the victim) instead of being refused."""
    srv = LLMServer(_cfg("qwen2.5-3b"), num_slots=1, capacity=64,
                    overload=OverloadPolicy(max_queue_depth=3))
    lows = [srv.submit(f"low {i}", _sp()) for i in range(3)]   # queue full
    with pytest.raises(OverloadError, match="queue full"):
        srv.submit("low overflow", _sp())
    hi = srv.submit("urgent", _sp(priority=2))                 # displaces
    victim = lows[-1]                            # youngest low-priority
    assert victim.status().value == "shed"
    assert isinstance(victim.request.error, ShedError)
    assert victim.request.finished
    srv.run_until_idle()
    assert hi.status().value == "completed"
    assert all(h.request.finished for h in lows)
    st = srv.stats()
    assert st["shed_requests"] == 1
    assert st["queued_requests"] == 0 and st["live_requests"] == 0
    srv.close()


def test_per_class_depth_cap():
    """class_depth bounds one priority class without touching others."""
    srv = LLMServer(_cfg("qwen2.5-3b"), num_slots=1, capacity=64,
                    overload=OverloadPolicy(class_depth={0: 2}))
    for i in range(2):
        srv.submit(f"batch {i}", _sp())
    with pytest.raises(OverloadError, match="class"):
        srv.submit("batch 2", _sp())
    hi = srv.submit("interactive", _sp(priority=1))   # class 1: unbounded
    srv.run_until_idle()
    assert hi.status().value == "completed"
    srv.close()


def test_queue_age_cap_sheds_stale_requests():
    srv = LLMServer(_cfg("qwen2.5-3b"), num_slots=1, capacity=128,
                    engine_cfg=EngineConfig(decode_chunk=2),
                    overload=OverloadPolicy(max_queue_age_s=0.05))
    runner = srv.submit("long running job " * 3, _sp(max_new=32))
    while runner.status().value != "running":
        srv.step()
    stale = srv.submit("will go stale", _sp())
    # wait on the condition itself (queued age past the cap), not a fixed
    # sleep: the sweep runs at the next step once the age cap is exceeded
    deadline = time.perf_counter() + 5.0
    while (time.perf_counter() - stale.request._submit_t) <= 0.05:
        assert time.perf_counter() < deadline
        time.sleep(0.005)
    srv.step()                                       # sweep runs first
    assert stale.status().value == "shed"
    assert isinstance(stale.request.error, ShedError)
    assert "age cap" in str(stale.request.error)
    srv.run_until_idle()
    assert runner.status().value == "completed"
    srv.close()


def test_predictive_deadline_shed():
    """With EWMA service-time data, a queued request whose remaining
    deadline cannot cover its predicted service time is shed immediately
    (typed) instead of burning a slot to time out anyway."""
    srv = LLMServer(_cfg("qwen2.5-3b"), num_slots=1, capacity=128,
                    engine_cfg=EngineConfig(decode_chunk=2),
                    overload=OverloadPolicy(shed_on_deadline=True))
    eng = srv.engine
    eng._svc_decode_tok_s = 10.0                     # 8 tokens -> eta 80s
    runner = srv.submit("long running job " * 3, _sp(max_new=32))
    while runner.status().value != "running":
        srv.step()
    doomed = srv.submit("tight deadline", _sp(deadline_s=5.0))
    srv.step()
    assert doomed.status().value == "shed"
    assert "predicted service time" in str(doomed.request.error)
    srv.run_until_idle()
    assert runner.status().value == "completed"
    srv.close()


# ---------------------------------------------------------------------------
# circuit breaker over dispatch dead-letters
# ---------------------------------------------------------------------------

def test_breaker_opens_after_consecutive_dead_letters_and_cools():
    srv = LLMServer(_cfg("qwen2.5-3b"), num_slots=1, capacity=64,
                    overload=OverloadPolicy(breaker_threshold=3,
                                            breaker_cooldown_s=0.1))
    eng = srv.engine
    for _ in range(2):
        eng._breaker_note(False)
    eng._breaker_note(True)                          # success resets streak
    for _ in range(3):
        eng._breaker_note(False)                     # threshold -> open
    assert srv.stats()["breaker_trips"] == 1
    assert srv.stats()["breaker_open"] is True
    with pytest.raises(OverloadError, match="breaker"):
        srv.submit("refused", _sp())
    # poll-submit until the cooldown elapses instead of sleeping a fixed
    # wall-clock amount (flaky on loaded CI runners)
    deadline = time.perf_counter() + 5.0
    while True:
        try:
            h = srv.submit("admitted again", _sp())
            break
        except OverloadError:
            assert time.perf_counter() < deadline, "breaker never cooled"
            time.sleep(0.005)
    srv.run_until_idle()
    assert h.status().value == "completed"
    srv.close()


def test_breaker_integration_with_injected_dead_letters():
    """Real dead-letters (seeded FaultInjector, no retry headroom) drive
    the breaker: repeated dispatch failures open it, and submits during
    the cooldown are refused typed."""
    inj = FaultInjector(seed=0)
    srv = LLMServer(_cfg("qwen2.5-3b"), num_slots=1, capacity=64,
                    injector=inj, retry=RetryPolicy(max_attempts=1),
                    overload=OverloadPolicy(breaker_threshold=2,
                                            breaker_cooldown_s=5.0))
    inj.fail_next("decode", 2)
    h1 = srv.submit("first doomed", _sp())
    srv.run_until_idle()
    h2 = srv.submit("second doomed", _sp())
    srv.run_until_idle()
    assert h1.status().value == "failed" and h2.status().value == "failed"
    assert srv.stats()["breaker_trips"] == 1
    with pytest.raises(OverloadError, match="breaker"):
        srv.submit("refused while open", _sp())
    srv.close()


# ---------------------------------------------------------------------------
# priority preemption: bit-identical resume across cache modes
# ---------------------------------------------------------------------------

MODES = [("qwen2.5-3b", "dense"), ("qwen2.5-3b", "paged"),
         ("recurrentgemma-9b", "paged")]


@pytest.mark.parametrize("arch,mode", MODES)
def test_preempt_resume_bit_identical(arch, mode):
    """A running low-priority decode preempted at the chunk boundary and
    resumed later must emit EXACTLY the uninterrupted output — same
    tokens and the same per-request RNG chain (temperature > 0: resume
    continues sampling at fold_in(key, k), not a fresh chain)."""
    cfg = _cfg(arch)
    ecfg = EngineConfig(cache_mode=mode, page_size=8, decode_chunk=2)
    lo_sp = _sp(max_new=24, temperature=0.7)
    ref_srv = LLMServer(cfg, num_slots=1, capacity=128, seed=7,
                        engine_cfg=ecfg)
    ref = ref_srv.submit("a long low priority ramble ", lo_sp)
    ref_srv.run_until_idle()
    ref_out = ref.result()
    params = ref_srv.params
    ref_srv.close()

    srv = LLMServer(cfg, num_slots=1, capacity=128, seed=7, params=params,
                    engine_cfg=ecfg, overload=OverloadPolicy(preempt=True))
    lo = srv.submit("a long low priority ramble ", lo_sp)    # same rid
    while lo.status().value != "running":
        srv.step()
    srv.step()
    hi = srv.submit("urgent", _sp(priority=5, temperature=0.7))
    srv.run_until_idle()
    assert lo.request.preempted >= 1, (arch, mode)
    assert hi.status().value == "completed"
    assert lo.status().value == "completed"
    assert lo.result() == ref_out, (arch, mode)
    st = srv.stats()
    assert st["preemptions"] >= 1 and st["preempt_resumes"] >= 1
    assert st["queued_requests"] == 0 and st["live_requests"] == 0
    eng = srv.engine
    if mode == "paged" and arch == "qwen2.5-3b":
        owned = eng.radix.check_invariants()
        free = set(eng.kvpool._free)
        assert not (owned & free)
        assert (len(owned) + len(free)
                == eng.kvpool.num_pages - eng.kvpool.reserved)
    srv.close()


def test_preempt_only_strictly_higher_priority():
    """Equal priority never preempts: FIFO within a class stays FIFO."""
    srv = LLMServer(_cfg("qwen2.5-3b"), num_slots=1, capacity=128,
                    engine_cfg=EngineConfig(decode_chunk=2),
                    overload=OverloadPolicy(preempt=True))
    first = srv.submit("first in class " * 2, _sp(max_new=16, priority=1))
    while first.status().value != "running":
        srv.step()
    second = srv.submit("second in class", _sp(priority=1))
    srv.run_until_idle()
    assert first.request.preempted == 0
    assert srv.stats()["preemptions"] == 0
    assert (first.status().value == second.status().value == "completed")
    srv.close()


def test_preempted_stream_stays_monotonic():
    """A handle mid-stream across a preempt/resume sees its text grow
    monotonically — no rewind, no duplicated chunk."""
    srv = LLMServer(_cfg("qwen2.5-3b"), num_slots=1, capacity=128,
                    engine_cfg=EngineConfig(decode_chunk=2),
                    overload=OverloadPolicy(preempt=True))
    lo = srv.submit("streaming ramble " * 2, _sp(max_new=24))
    while lo.status().value != "running":
        srv.step()
    srv.step()
    hi = srv.submit("urgent", _sp(priority=5))
    seen = ""
    for chunk in lo.stream():
        seen += chunk
    assert lo.request.preempted >= 1
    # no rewind, no duplicated chunk across the preempt/resume boundary:
    # the streamed increments concatenate to exactly the final output
    assert seen == lo.request.output_text
    assert hi.status().value == "completed"
    srv.close()


# ---------------------------------------------------------------------------
# hypothesis: threaded submit / cancel / preempt interleavings vs a pumping
# server preserve exactly-once page ownership
# ---------------------------------------------------------------------------

_LOAD_SRV = None


def _load_server():
    global _LOAD_SRV
    if _LOAD_SRV is None:
        # tiny pool (eviction pressure) + spec (rejection pressure) + tiny
        # chunks (many preempt windows) + tight queue (shed pressure),
        # driven through the background pump from racing client threads
        _LOAD_SRV = LLMServer(
            _cfg("qwen2.5-3b"), num_slots=2, capacity=64,
            engine_cfg=EngineConfig(cache_mode="paged", page_size=8,
                                    num_pages=18, spec_len=4,
                                    decode_chunk=2),
            overload=OverloadPolicy(max_queue_depth=4, preempt=True),
            pump=True)
    return _LOAD_SRV


def _run_threaded_ops(ops):
    """Fire submit(lo) / submit(hi) / submit-then-cancel / pause ops from
    three racing client threads at a pumping, overloadable server
    (displacement sheds + chunk-boundary preemptions + draft rejections +
    LRU eviction all active): after the drain, every page must be owned
    exactly once — free list or radix tree — and every handle terminal."""
    srv = _load_server()
    pool = ["err 429 err 429 err 429. " + t for t in
            ("", "tail one", "go go go go go", "a longer tail that repeats")]
    handles, lock = [], threading.Lock()

    def client(shard):
        for kind, variant, budget in shard:
            try:
                if kind == 0:                      # low-priority submit
                    h = srv.submit(pool[variant], _sp(max_new=budget))
                elif kind == 1:                    # high-priority submit
                    h = srv.submit(pool[variant],
                                   _sp(max_new=budget, priority=2))
                elif kind == 2:                    # submit then racy cancel
                    h = srv.submit(pool[variant], _sp(max_new=budget))
                    srv.cancel(h)
                else:
                    time.sleep(0.002)
                    continue
            except OverloadError:
                continue                           # typed refusal is fine
            with lock:
                handles.append(h)

    shards = [[op[1:] for op in ops if op[0] == t] for t in range(3)]
    threads = [threading.Thread(target=client, args=(s,)) for s in shards]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    srv.run_until_idle()
    assert all(h.request.finished for h in handles)
    terminal = {"completed", "cancelled", "timed_out", "failed", "shed"}
    assert all(h.request.status in terminal for h in handles)
    eng = srv.engine
    assert not eng._queue and all(s.request is None for s in eng.slots)
    owned = eng.radix.check_invariants()
    free = set(eng.kvpool._free)
    assert not (owned & free)
    assert len(owned) + len(free) == eng.kvpool.num_pages - eng.kvpool.reserved


@given(st.lists(st.tuples(st.integers(0, 2),      # client thread
                          st.integers(0, 3),      # op kind
                          st.integers(0, 3),      # prompt variant
                          st.integers(2, 12)),    # token budget
                min_size=4, max_size=12))
@settings(max_examples=15, deadline=None)
def test_threaded_interleavings_exactly_once_ownership(ops):
    _run_threaded_ops(ops)


def test_threaded_interleavings_fixed_script():
    """Deterministic stand-in for the hypothesis sweep (which needs the
    hypothesis package): a dense script mixing all op kinds across the
    three client threads."""
    _run_threaded_ops([(t, k, (t + k) % 4, 3 + 2 * k)
                       for t in range(3) for k in range(4)])


def test_threaded_snapshot_ownership():
    """The snapshot-arena twin of the page test on a stateful arch: racing
    submits/cancels with preemption active never leak or double-free a
    state snapshot."""
    srv = LLMServer(
        _cfg("recurrentgemma-9b"), num_slots=2, capacity=64,
        engine_cfg=EngineConfig(cache_mode="paged", decode_chunk=2),
        overload=OverloadPolicy(max_queue_depth=4, preempt=True),
        pump=True)
    with srv:
        def client(i):
            for j in range(3):
                try:
                    h = srv.submit(f"stateful {i} turn {j} " * 2,
                                   _sp(max_new=6, priority=j % 2))
                except OverloadError:
                    continue
                if (i + j) % 3 == 0:
                    srv.cancel(h)
        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(3)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        srv.run_until_idle()
        eng = srv.engine
        assert not eng._queue and all(s.request is None for s in eng.slots)
        owned = eng.radix.check_invariants(snapshots=True)
        free = set(eng.snaps._free)
        assert not (owned & free)
        assert len(owned) + len(free) == eng.snaps.num_snaps
