"""Elastic scaling: mesh re-derivation + checkpoint reshard-on-restore."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint.checkpoint import restore, save
from repro.distributed.elastic import (best_mesh_shape, make_elastic_mesh,
                                       reshard_tree)


def test_best_mesh_shape_degrades_gracefully():
    assert best_mesh_shape(512) == (32, 16)     # two pods
    assert best_mesh_shape(256) == (16, 16)     # one pod
    assert best_mesh_shape(240) == (15, 16)     # lost one host of 16
    assert best_mesh_shape(252) == (63, 4)      # lost 4 chips: TP degrades
    assert best_mesh_shape(13) == (13, 1)       # prime survivor count
    assert best_mesh_shape(1) == (1, 1)


def test_checkpoint_restores_onto_new_mesh(tmp_path):
    """Save under one layout, restore under another (elastic restart)."""
    d = str(tmp_path / "ckpt")
    tree = {"w": jnp.arange(64.0).reshape(8, 8), "step": jnp.array(7)}
    save(d, 7, tree)
    mesh = make_elastic_mesh(jax.devices())      # 1 CPU -> (1, 1)
    shardings = {"w": NamedSharding(mesh, P("data", "model")),
                 "step": NamedSharding(mesh, P())}
    got, step = restore(d, tree, shardings=shardings)
    assert step == 7
    assert jnp.array_equal(got["w"], tree["w"])
    assert got["w"].sharding == shardings["w"]


def test_reshard_tree_places_leaves():
    mesh = make_elastic_mesh(jax.devices())
    tree = {"a": jnp.ones((4, 4)), "b": (jnp.zeros((2,)),)}
    ps = {"a": P(None, None), "b": (P(None),)}
    out = reshard_tree(tree, mesh, ps)
    assert out["a"].sharding.mesh.shape == dict(mesh.shape)
