"""Drafter-free speculative decoding: n-gram drafter, batched accept
(greedy exact-match + rejection sampling), verify-mode forward vs per-token
decode, engine spec-vs-baseline equivalence (incl. recurrent/ring rollback),
paged page-leak freedom, and radix-aware admission grouping."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import ARCHS
from repro.kernels.spec_scan import accept_len, accept_len_ref
from repro.models import Model
from repro.models import attention as attn
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.sampler import accept_batched, sample_batched
from repro.serving.spec import NgramDrafter

from tests._hypothesis_compat import given, settings, st


def _cfg(arch, **over):
    return ARCHS[arch].reduced(dtype="float32", param_dtype="float32",
                               vocab_size=512, **over)


# ---------------------------------------------------------------------------
# n-gram drafter
# ---------------------------------------------------------------------------


def test_drafter_lookup_and_self_extension():
    d = NgramDrafter([1, 2, 3, 4, 9, 1, 2, 3], n_min=2, n_max=3)
    # suffix (2,3) (and (1,2,3)) occurred before, continuation 4, 9, ...
    assert d.draft(2) == [4, 9]
    # self-extension: the chained lookup keeps drafting past the first span
    assert d.draft(5) == [4, 9, 1, 2, 3]
    assert d.draft(0) == []


def test_drafter_never_matches_itself():
    # the suffix's only occurrence is itself -> no draft
    assert NgramDrafter([5, 6, 7, 8], n_min=2, n_max=4).draft(4) == []
    # a period-1 loop drafts indefinitely via self-extension
    d = NgramDrafter([3, 7, 7, 7], n_min=2, n_max=4)
    assert d.draft(6) == [7] * 6


def test_drafter_incremental_extend_matches_fresh_build():
    seq = [1, 2, 3, 1, 2, 4, 1, 2, 3, 1]
    inc = NgramDrafter(seq[:4])
    for t in seq[4:]:
        inc.extend([t])
    fresh = NgramDrafter(seq)
    assert inc._map == fresh._map
    assert inc.draft(4) == fresh.draft(4)


# ---------------------------------------------------------------------------
# accept_batched: greedy exact-match semantics
# ---------------------------------------------------------------------------


def _onehotish(rows, V=16):
    """Logits whose argmax sequence per row is ``rows``."""
    return jnp.stack([jax.nn.one_hot(jnp.asarray(r), V) * 5.0 for r in rows])


def test_accept_greedy_full_accept_plus_bonus():
    # target argmaxes: 7, 3, 9 ; drafts d1=7, d2=3 -> both accepted, bonus 9
    logits = _onehotish([[7, 3, 9]])
    inputs = jnp.asarray([[1, 7, 3]], jnp.int32)
    out, n = accept_batched(logits, inputs, jnp.asarray([2]), None,
                            temperature=None)
    assert n.tolist() == [3]
    assert out[0, :3].tolist() == [7, 3, 9]


def test_accept_greedy_reject_emits_correction():
    # d1=7 accepted, d2=5 != argmax 3 -> rejected, correction = 3
    logits = _onehotish([[7, 3, 9]])
    inputs = jnp.asarray([[1, 7, 5]], jnp.int32)
    out, n = accept_batched(logits, inputs, jnp.asarray([2]), None,
                            temperature=None)
    assert n.tolist() == [2]
    assert out[0, :2].tolist() == [7, 3]


def test_accept_zero_draft_is_plain_decode_step():
    logits = _onehotish([[4, 0, 0], [2, 0, 0]])
    inputs = jnp.asarray([[1, 0, 0], [3, 0, 0]], jnp.int32)
    out, n = accept_batched(logits, inputs, jnp.asarray([0, 0]), None,
                            temperature=None)
    assert n.tolist() == [1, 1]
    assert out[:, 0].tolist() == [4, 2]
    # matches sample_batched on the same logits
    ref = sample_batched(logits[:, 0], None, temperature=None)
    assert out[:, 0].tolist() == ref.tolist()


def test_accept_vocab_limit_respected():
    logits = jnp.zeros((1, 2, 16)).at[0, :, 13].set(9.0)   # argmax beyond limit
    inputs = jnp.asarray([[1, 2]], jnp.int32)
    out, n = accept_batched(logits, inputs, jnp.asarray([1]), None,
                            temperature=None, vocab_limit=8)
    assert int(out[0, 0]) < 8


# ---------------------------------------------------------------------------
# accept_batched: rejection sampling is distribution-correct
# ---------------------------------------------------------------------------


def _marginal(logits_row, draft_tok, temperature, top_k, n=4000):
    """Empirical distribution of the FIRST emitted token when ``draft_tok``
    is proposed against target logits ``logits_row``."""
    logits = jnp.asarray(logits_row, jnp.float32)[None, None, :]
    logits = jnp.concatenate([logits, jnp.zeros_like(logits)], axis=1)
    inputs = jnp.asarray([[0, draft_tok]], jnp.int32)
    temps = jnp.asarray([temperature], jnp.float32)
    ks = None if top_k is None else jnp.asarray([top_k], jnp.int32)

    def one(key):
        out, _ = accept_batched(logits, inputs, jnp.asarray([1]), key,
                                temperature=temps, top_k=ks)
        return out[0, 0]

    toks = jax.jit(jax.vmap(one))(jax.random.split(jax.random.PRNGKey(0), n))
    V = logits.shape[-1]
    return jnp.bincount(toks, length=V) / n


def test_rejection_sampling_marginals_match_target():
    """Fixed-seed statistical check (ISSUE 3 acceptance criterion): with a
    deterministic drafter, accept-with-prob-p(d) + renormalized-residual
    resampling leaves every per-token marginal equal to non-speculative
    sampling — whether the draft is likely, unlikely, or top-k-excluded."""
    logits_row = [1.0, 2.0, 0.5, -0.5, 1.5, 0.0, -1.0, 0.7]
    target = jax.nn.softmax(jnp.asarray(logits_row))
    for d in (1, 6):                       # likely and unlikely draft
        emp = _marginal(logits_row, d, 1.0, None)
        assert float(jnp.max(jnp.abs(emp - target))) < 0.03, (d, emp, target)
    # with top-k filtering the target is the renormalized top-3; draft 6 is
    # outside the filter (p=0 -> always rejected, residual == target)
    scaled = jnp.asarray(logits_row)
    kth = jnp.sort(scaled)[-3]
    t3 = jax.nn.softmax(jnp.where(scaled >= kth, scaled, -1e30))
    for d in (1, 6):
        emp = _marginal(logits_row, d, 1.0, 3)
        assert float(jnp.max(jnp.abs(emp - t3))) < 0.03, (d, emp, t3)


# ---------------------------------------------------------------------------
# fused accept-length scan kernel (interpret mode) vs reference
# ---------------------------------------------------------------------------


def test_accept_len_kernel_matches_ref():
    key = jax.random.PRNGKey(3)
    acc = jax.random.bernoulli(key, 0.6, (5, 9))
    lens = jnp.asarray([0, 3, 8, 8, 5], jnp.int32)
    out = accept_len(acc, lens)
    ref = accept_len_ref(acc, lens)
    assert out.tolist() == ref.tolist()
    # directed edges: all-accept hits the len cap; first-reject cuts to 0
    assert accept_len(jnp.ones((1, 4), bool), jnp.asarray([3])).tolist() == [3]
    assert accept_len(jnp.zeros((1, 4), bool), jnp.asarray([3])).tolist() == [0]


# ---------------------------------------------------------------------------
# verify-mode forward == sequential decode steps (logits and cache writes)
# ---------------------------------------------------------------------------


def test_verify_logits_match_sequential_decode():
    cfg = _cfg("qwen2.5-3b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    P, S, cap = 11, 5, 64
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (1, P + S), 0, cfg.vocab_size)
    cache = model.init_cache(1, cap)
    _, cache = model.prefill(params, model.make_batch(toks[:, :P]), cache,
                             length=jnp.int32(P))
    vb = model.make_batch(toks[:, P:], start=P)
    logits_v, cache_v = model.verify(params, vb, cache,
                                     jnp.asarray([P], jnp.int32),
                                     lens=jnp.asarray([S], jnp.int32))
    ref, c = [], cache
    for i in range(S):
        lg, c = model.decode_step(params,
                                  model.make_batch(toks[:, P + i:P + i + 1],
                                                   start=P + i),
                                  c, jnp.asarray([P + i], jnp.int32))
        ref.append(lg[:, 0])
    ref = jnp.stack(ref, axis=1)
    assert float(jnp.max(jnp.abs(logits_v - ref))) < 1e-4
    # and the written K/V agrees with the sequential path
    for leaf_v, leaf_r in zip(jax.tree.leaves(cache_v), jax.tree.leaves(c)):
        assert float(jnp.max(jnp.abs(leaf_v - leaf_r))) < 1e-4


def test_spec_cache_update_drops_invalid_rows():
    kc = jnp.zeros((2, 8, 1, 2))
    knew = jnp.ones((2, 3, 1, 2))
    clens = jnp.asarray([1, 5], jnp.int32)
    valid = jnp.asarray([[True, True, False], [True, False, False]])
    kc2, _ = attn.spec_cache_update(kc, kc, knew, knew, clens, valid)
    assert float(jnp.sum(kc2)) == 3 * 2          # 3 valid writes x K*hd
    assert float(kc2[0, 1, 0, 0]) == 1.0 and float(kc2[0, 2, 0, 0]) == 1.0
    assert float(kc2[0, 3, 0, 0]) == 0.0         # invalid row dropped
    assert float(kc2[1, 5, 0, 0]) == 1.0 and float(kc2[1, 6, 0, 0]) == 0.0


# ---------------------------------------------------------------------------
# mode="extend" multi-position logits == per-token decode (ISSUE 3 satellite)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "recurrentgemma-9b",
                                  "xlstm-350m"])
def test_extend_all_logits_match_per_token_decode(arch):
    """One ``extend`` call with ``with_logits="all"`` must return, at every
    chunk position, the same logits a per-token decode loop produces — the
    contract the per-slot speculative verify path (and its recurrent/ring
    rollback replay) is built on."""
    cfg = _cfg(arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    P, S, cap = 9, 6, 64
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, P + S), 0,
                              cfg.vocab_size)
    cache = model.init_cache(1, cap)
    _, cache = model.prefill(params, model.make_batch(toks[:, :P]), cache,
                             length=jnp.int32(P))
    logits_e, _ = model.extend(params, model.make_batch(toks[:, P:], start=P),
                               cache, jnp.int32(P), length=jnp.int32(S),
                               with_logits="all")
    ref, c = [], cache
    for i in range(S):
        lg, c = model.decode_step(params,
                                  model.make_batch(toks[:, P + i:P + i + 1],
                                                   start=P + i),
                                  c, jnp.int32(P + i))
        ref.append(lg[:, 0])
    ref = jnp.stack(ref, axis=1)
    assert float(jnp.max(jnp.abs(logits_e - ref))) < 2e-4, arch
    # "last" slices the same tensor down to the final position
    logits_l, _ = model.extend(params, model.make_batch(toks[:, P:], start=P),
                               cache, jnp.int32(P), length=jnp.int32(S),
                               with_logits="last")
    assert logits_l.shape[1] == 1
    assert float(jnp.max(jnp.abs(logits_l[:, 0] - logits_e[:, -1]))) < 1e-5


# ---------------------------------------------------------------------------
# engine: speculative == non-speculative, bit for bit (greedy)
# ---------------------------------------------------------------------------

COPY_PROMPTS = [
    "Tool result: ERROR 429 rate limit exceeded at gateway. " * 2,
    "summarize: the quick brown fox jumps over the lazy dog again and "
    "again and again",
    "log: a=1 b=2; log: a=1 b=2; log: a=1 b=3; what changed?",
]


@pytest.mark.parametrize("mode", ["dense", "paged"])
def test_spec_greedy_identical_batched_verify(mode):
    cfg = _cfg("qwen2.5-3b")
    base = ServingEngine(cfg, num_slots=3, capacity=160,
                         engine_cfg=EngineConfig(cache_mode=mode))
    spec = ServingEngine(cfg, num_slots=3, capacity=160, params=base.params,
                         engine_cfg=EngineConfig(cache_mode=mode, spec_len=6))
    b = [base.generate(p, max_new_tokens=40) for p in COPY_PROMPTS]
    s = [spec.generate(p, max_new_tokens=40) for p in COPY_PROMPTS]
    assert b == s
    st = spec.stats()
    assert st["verify_steps"] > 0 and st["draft_tokens"] > 0
    assert st["accepted_tokens"] > 0
    assert 0.0 < st["acceptance_rate"] <= 1.0
    base_st = base.stats()
    assert base_st["verify_steps"] == 0 and base_st["draft_tokens"] == 0


@pytest.mark.parametrize("arch", ["recurrentgemma-9b", "xlstm-350m",
                                  "mixtral-8x22b"])
def test_spec_greedy_identical_stateful_batched_verify(arch):
    """Stateful archs (recurrent / conv / xLSTM state, ring KV) now take the
    same ONE-jit'd-verify-per-step path as full attention: per-position
    states staged during the forward, accept-length state rewind inside the
    verify jit. A rejected draft must leave recurrent state and ring caches
    exactly as non-speculative decode builds them."""
    cfg = _cfg(arch)
    base = ServingEngine(cfg, num_slots=2, capacity=128)
    spec = ServingEngine(cfg, num_slots=2, capacity=128, params=base.params,
                         engine_cfg=EngineConfig(spec_len=6))
    b = [base.generate(p, max_new_tokens=40) for p in COPY_PROMPTS[:2]]
    s = [spec.generate(p, max_new_tokens=40) for p in COPY_PROMPTS[:2]]
    assert b == s, arch
    # batched means batched: one host sync per verify step (the per-slot
    # replay path — 1-2 syncs per drafted slot per step — is gone)
    st = spec.stats()
    assert st["host_syncs"] == st["verify_steps"] + st["decode_chunks"]
    assert not hasattr(spec, "_jit_spec_extend")


def test_spec_stateful_batched_submit_freezes_sitting_rows():
    """Regression: a spec-handled slot sits the same step's decode chunk out
    via the done mask — the chunk must then FREEZE that row's recurrent /
    conv / mLSTM / sLSTM state (a stale-input state advance is not
    idempotent the way a full-attention re-write is). Batched submits with
    interleaved verify + chunk steps diverged from base before the
    engine's done-row state freeze."""
    cfg = _cfg("xlstm-350m")
    base = ServingEngine(cfg, num_slots=3, capacity=160)
    spec = ServingEngine(cfg, num_slots=3, capacity=160, params=base.params,
                         engine_cfg=EngineConfig(spec_len=6))
    prompts = [f"[agent {i}] status flaps: " + "err 429; ok 200; " * 6
               for i in range(3)]
    outs = {}
    for name, eng in (("base", base), ("spec", spec)):
        reqs = [eng.submit(p, max_new_tokens=48) for p in prompts]
        eng.run_until_drained()
        outs[name] = [r.output_text for r in reqs]
    assert outs["base"] == outs["spec"]


def test_spec_mixed_batch_and_queue_pressure():
    """More requests than slots with speculation on: FIFO admission, slot
    recycling, and exact token budgets all survive the verify path."""
    cfg = _cfg("qwen2.5-3b")
    base = ServingEngine(cfg, num_slots=2, capacity=128)
    spec = ServingEngine(cfg, num_slots=2, capacity=128, params=base.params,
                         engine_cfg=EngineConfig(spec_len=5))
    for eng in (base, spec):
        reqs = [eng.submit(COPY_PROMPTS[i % 3], max_new_tokens=12 + i)
                for i in range(6)]
        eng.run_until_drained()
        assert all(r.output_tokens == 12 + i for i, r in enumerate(reqs))
    b = [base.generate(p, max_new_tokens=16) for p in COPY_PROMPTS]
    s = [spec.generate(p, max_new_tokens=16) for p in COPY_PROMPTS]
    assert b == s


def test_spec_sampling_deterministic_and_bounded():
    """Stochastic slots under speculation: same seed -> same text, and the
    rejection-sampled tokens stay inside the vocab limit."""
    cfg = _cfg("qwen2.5-3b")
    e1 = ServingEngine(cfg, num_slots=2, capacity=128, seed=5,
                       engine_cfg=EngineConfig(spec_len=5))
    e2 = ServingEngine(cfg, num_slots=2, capacity=128, params=e1.params,
                       seed=5, engine_cfg=EngineConfig(spec_len=5))
    a = e1.generate(COPY_PROMPTS[0], max_new_tokens=24, temperature=1.2,
                    top_k=20)
    b = e2.generate(COPY_PROMPTS[0], max_new_tokens=24, temperature=1.2,
                    top_k=20)
    assert a == b


def test_spec_adaptive_disable_falls_back_to_chunked():
    """An impossible acceptance floor turns per-slot drafting off after the
    warmup; outputs stay identical and decode continues through the chunked
    loop (the interleave contract)."""
    cfg = _cfg("qwen2.5-3b")
    base = ServingEngine(cfg, num_slots=1, capacity=128)
    spec = ServingEngine(cfg, num_slots=1, capacity=128, params=base.params,
                         engine_cfg=EngineConfig(spec_len=6,
                                                 spec_min_accept=1.1,
                                                 spec_warmup=1))
    p = COPY_PROMPTS[0]
    assert spec.generate(p, max_new_tokens=40) == \
        base.generate(p, max_new_tokens=40)
    st = spec.stats()
    assert st["verify_steps"] <= 2          # disabled after the first verify
    assert st["decode_chunks"] > 0


def test_spec_rejects_non_text_modality():
    with pytest.raises(ValueError):
        ServingEngine(ARCHS["musicgen-large"].reduced(
            dtype="float32", param_dtype="float32"),
            num_slots=1, capacity=64, engine_cfg=EngineConfig(spec_len=4))


def test_spec_len_must_be_non_negative():
    with pytest.raises(ValueError):
        ServingEngine(_cfg("qwen2.5-3b"), num_slots=1, capacity=64,
                      engine_cfg=EngineConfig(spec_len=-1))


# ---------------------------------------------------------------------------
# paged: no page leak under speculative rollback (hypothesis)
# ---------------------------------------------------------------------------

_LEAK_ENGINE = None


def _leak_engine():
    global _LEAK_ENGINE
    if _LEAK_ENGINE is None:
        cfg = _cfg("qwen2.5-3b")
        # decode_chunk=4 so small budgets still interleave verify steps with
        # the chunked loop (checked below: speculation must actually fire)
        _LEAK_ENGINE = ServingEngine(
            cfg, num_slots=2, capacity=64,
            engine_cfg=EngineConfig(cache_mode="paged", page_size=16,
                                    num_pages=12, spec_len=5,
                                    decode_chunk=4))
    return _LEAK_ENGINE


@given(st.lists(st.tuples(st.integers(0, 3), st.integers(2, 20)),
                min_size=4, max_size=14))
@settings(max_examples=60, deadline=None)
def test_spec_paged_no_page_leak(reqs):
    """~500 speculative paged requests across examples (shared prefixes,
    random token budgets, LRU eviction pressure from the deliberately small
    pool, frequent draft rejections): after every drain each page is owned
    exactly once — free list or radix tree — so rejected-draft rollback
    never leaks or double-frees a page."""
    eng = _leak_engine()
    # repetitive prompts so the n-gram drafter fires (and gets rejected a
    # lot at these tiny budgets — the rollback path is the test subject)
    pool = ["err 429 err 429 err 429. " + t for t in
            ("", "tail one", "go go go go go", "a longer tail that spills "
             "pages and repeats repeats repeats")]
    for variant, budget in reqs:
        eng.submit(pool[variant], max_new_tokens=budget)
    eng.run_until_drained()
    assert all(s.request is None for s in eng.slots)
    owned = eng.radix.check_invariants()
    free = eng.kvpool.num_free
    assert len(owned) + free == eng.kvpool.num_pages - eng.kvpool.reserved
    assert not (owned & set(eng.kvpool._free))


def test_spec_paged_leak_engine_speculated():
    """Companion gate for the property above (also its no-hypothesis
    fallback): run a seeded request stream through the shared engine and
    require that verify steps actually happened — a silent
    never-speculated run would make the leak property vacuous."""
    import random
    eng = _leak_engine()
    rng = random.Random(0)
    pool = ["err 429 err 429 err 429. " + t for t in
            ("", "tail one", "go go go go go", "a longer tail that spills "
             "pages and repeats repeats repeats")]
    for _ in range(8):
        for _ in range(rng.randint(4, 14)):
            eng.submit(pool[rng.randrange(4)],
                       max_new_tokens=rng.randint(2, 20))
        eng.run_until_drained()
        owned = eng.radix.check_invariants()
        assert (len(owned) + eng.kvpool.num_free
                == eng.kvpool.num_pages - eng.kvpool.reserved)
    st = eng.stats()
    assert st["verify_steps"] > 0 and st["draft_tokens"] > 0
    assert eng.radix.evicted_pages > 0


# ---------------------------------------------------------------------------
# radix-aware admission batching (ISSUE 3 satellite)
# ---------------------------------------------------------------------------


def test_radix_grouped_admission_counts_and_outputs():
    """Queued requests sharing the admitted request's first radix block jump
    (stably) to the queue front and admit in the same engine step; the
    grouping is counted and never changes any request's output."""
    cfg = _cfg("qwen2.5-3b")
    sys_a = "SYSTEM PROMPT ALPHA shared by planner/actor/evaluator. "
    sys_b = "system prompt bravo shared by a second workflow here. "
    prompts = [sys_a + "plan the step", sys_b + "plan the step",
               sys_a + "act on the step", sys_b + "act now please",
               sys_a + "evaluate result", sys_b + "evaluate please"]
    dense = ServingEngine(cfg, num_slots=3, capacity=96)
    paged = ServingEngine(cfg, num_slots=3, capacity=96, params=dense.params,
                          engine_cfg=EngineConfig(cache_mode="paged",
                                                  page_size=16))
    for p in prompts:
        paged.submit(p, max_new_tokens=8)
    paged.run_until_drained()
    s = paged.stats()
    # admitting the first ALPHA request pulls the other two ALPHAs into the
    # same step (and likewise for BRAVO once it reaches the head)
    assert s["grouped_admissions"] >= 2
    d = [dense.generate(p, max_new_tokens=8) for p in prompts]
    p2 = [paged.generate(p, max_new_tokens=8) for p in prompts]
    assert d == p2
    # a lone request never groups with itself
    lone = ServingEngine(cfg, num_slots=1, capacity=96, params=dense.params,
                         engine_cfg=EngineConfig(cache_mode="paged"))
    lone.generate("just one request", max_new_tokens=4)
    assert lone.stats()["grouped_admissions"] == 0
