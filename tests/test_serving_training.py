"""Serving engine, tokenizer, training loop, data pipeline tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.registry import ARCHS
from repro.serving.engine import ServingEngine
from repro.serving.tokenizer import ByteTokenizer
from repro.training.data import DataConfig, SyntheticLM
from repro.training.optimizer import AdamWConfig, init_opt_state
from repro.training.train_step import (TrainConfig, compress_int8,
                                       decompress_int8, make_train_step)


# ---------------------------------------------------------------------------
# tokenizer
# ---------------------------------------------------------------------------


@settings(deadline=None, max_examples=30)
@given(st.text(alphabet=st.characters(min_codepoint=32, max_codepoint=126),
               min_size=0, max_size=200))
def test_tokenizer_roundtrip_ascii(text):
    tok = ByteTokenizer(512)
    assert tok.decode(tok.encode(text)) == text


def test_tokenizer_respects_vocab_size():
    for v in (512, 2048, 50304):
        tok = ByteTokenizer(v)
        ids = tok.encode("the quick brown fox " * 20)
        assert max(ids) < v


# ---------------------------------------------------------------------------
# serving engine
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def engine():
    cfg = ARCHS["qwen2.5-3b"].reduced(dtype="float32", param_dtype="float32",
                                      vocab_size=512)
    return ServingEngine(cfg, num_slots=3, capacity=96)


def test_engine_batched_equals_sequential(engine):
    """Continuous batching must not change any request's output."""
    prompts = [f"prompt number {i} with some text" for i in range(4)]
    # sequential: one at a time
    seq_out = []
    for p in prompts:
        seq_out.append(engine.generate(p, max_new_tokens=8))
    # batched: all at once through 3 slots
    reqs = [engine.submit(p, max_new_tokens=8) for p in prompts]
    engine.run_until_drained()
    assert [r.output_text for r in reqs] == seq_out


def test_engine_tracks_tokens(engine):
    req = engine.submit("hello world", max_new_tokens=5)
    engine.run_until_drained()
    assert req.prompt_tokens > 0 and req.output_tokens == 5


# ---------------------------------------------------------------------------
# training
# ---------------------------------------------------------------------------


def test_loss_decreases_on_structured_data():
    cfg = ARCHS["granite-3-2b"].reduced(dtype="float32", param_dtype="float32",
                                        vocab_size=256, num_layers=2)
    from repro.models import Model
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    dcfg = DataConfig(global_batch=8, seq_len=64, vocab_size=cfg.vocab_size)
    data = SyntheticLM(dcfg, cfg)
    step = jax.jit(make_train_step(cfg, TrainConfig(opt=AdamWConfig(
        lr=1e-2, warmup_steps=5, total_steps=80))))
    opt = init_opt_state(params)
    losses = []
    for i in range(60):
        batch = data.batch_at(i)
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(dcfg.seq_len, dtype=jnp.int32), batch["labels"].shape)
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 1.5, (losses[0], losses[-1])


def test_grad_accumulation_equivalent():
    cfg = ARCHS["qwen2.5-3b"].reduced(dtype="float32", param_dtype="float32",
                                      num_layers=2)
    from repro.models import Model
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    data = SyntheticLM(DataConfig(global_batch=4, seq_len=32,
                                  vocab_size=cfg.vocab_size), cfg)
    batch = data.batch_at(0)
    outs = {}
    for accum in (1, 2, 4):
        step = jax.jit(make_train_step(cfg, TrainConfig(accum_steps=accum)))
        p2, _, m = step(params, init_opt_state(params), batch)
        outs[accum] = (float(m["loss"]), p2)
    assert outs[1][0] == pytest.approx(outs[2][0], rel=1e-4)
    deltas = [float(jnp.max(jnp.abs(a - b))) for a, b in zip(
        jax.tree.leaves(outs[1][1]), jax.tree.leaves(outs[4][1]))]
    assert max(deltas) < 5e-3


def test_int8_grad_compression_bounded_error():
    g = jax.random.normal(jax.random.PRNGKey(0), (256, 256)) * 0.01
    q, s = compress_int8(g)
    back = decompress_int8(q, s)
    assert q.dtype == jnp.int8
    assert float(jnp.max(jnp.abs(back - g))) <= float(s) + 1e-9


def test_data_pipeline_deterministic_and_restartable():
    d1 = SyntheticLM(DataConfig(seed=7, global_batch=2, seq_len=16))
    d2 = SyntheticLM(DataConfig(seed=7, global_batch=2, seq_len=16))
    for step in (0, 5, 99):
        a, b = d1.batch_at(step), d2.batch_at(step)
        assert jnp.array_equal(a["tokens"], b["tokens"])
    assert not jnp.array_equal(d1.batch_at(0)["tokens"], d1.batch_at(1)["tokens"])
