"""Paged KV pool + radix prefix sharing: allocator/trie invariants, the
paged decode-attention kernel vs the dense reference, and paged-vs-dense
engine equivalence (greedy outputs must be identical)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import ARCHS
from repro.kernels.paged_decode_attention import paged_decode_attention
from repro.models import attention as attn
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.kvpool import PagePool, block_table_array, supports_paged
from repro.serving.radix import RadixTree

from tests._hypothesis_compat import given, settings, st

PAGED_ARCHS = ["qwen2.5-3b", "chatglm3-6b", "granite-3-2b"]


def _cfg(arch):
    return ARCHS[arch].reduced(dtype="float32", param_dtype="float32",
                               vocab_size=512)


# ---------------------------------------------------------------------------
# page allocator
# ---------------------------------------------------------------------------


def test_page_pool_alloc_free_roundtrip():
    pool = PagePool(10)                       # page 0 reserved (trash)
    a = pool.alloc(4)
    b = pool.alloc(5)
    assert pool.num_free == 0 and pool.alloc(1) is None
    assert 0 not in a + b and len(set(a + b)) == 9
    pool.free(b)
    assert pool.num_free == 5
    with pytest.raises(ValueError):
        pool.free(b[:1])                      # double free
    with pytest.raises(ValueError):
        pool.free([0])                        # reserved page
    assert pool.alloc(6) is None              # all-or-nothing
    assert pool.num_free == 5


def test_block_table_padding_points_at_trash():
    bt = block_table_array([[3, 1], [], [2, 5, 7]], 4)
    assert bt.shape == (3, 4) and bt.dtype == jnp.int32
    assert bt[0].tolist() == [3, 1, 0, 0]
    assert bt[1].tolist() == [0, 0, 0, 0]
    assert bt[2].tolist() == [2, 5, 7, 0]


def test_supports_paged_gating():
    assert supports_paged(_cfg("qwen2.5-3b"))[0]
    assert supports_paged(_cfg("dbrx-132b"))[0]
    for arch in ("recurrentgemma-9b", "xlstm-350m", "mixtral-8x22b"):
        ok, why = supports_paged(_cfg(arch))
        assert not ok and why
        # ... but cache_mode="paged" still works: stateful archs resolve to
        # per-prefix recurrent-state snapshot sharing (tests/test_snapshots)
        eng = ServingEngine(_cfg(arch), num_slots=1, capacity=64,
                            engine_cfg=EngineConfig(cache_mode="paged"))
        assert eng.snapshots and not eng.paged
    full = ServingEngine(_cfg("qwen2.5-3b"), num_slots=1, capacity=64,
                         engine_cfg=EngineConfig(cache_mode="paged"))
    assert full.paged and not full.snapshots


# ---------------------------------------------------------------------------
# radix tree: directed cases + property test
# ---------------------------------------------------------------------------


def test_radix_match_insert_evict_basic():
    t = RadixTree(4)
    toks = list(range(11))                    # 2 complete blocks + remainder
    pages, node = t.match(toks)
    assert pages == [] and node is t.root
    assert t.insert(toks, [5, 6]) == []
    t.release(node)
    pages, node = t.match(toks)
    assert pages == [5, 6]
    # diverging suffix shares only the first block
    pages2, node2 = t.match(list(range(4)) + [99, 98, 97, 96])
    assert pages2 == [5]
    # pinned nodes (and their ancestors) survive eviction
    assert t.evict(10) == []
    t.release(node)
    assert t.evict(10) == [6]                 # leaf first; [5] still pinned via node2
    t.release(node2)
    assert t.evict(10) == [5]
    assert t.num_nodes == 0


def test_radix_insert_collision_returns_duplicates():
    t = RadixTree(2)
    assert t.insert([1, 2, 3, 4], [7, 8]) == []
    # identical blocks raced through prefill with different pages
    assert t.insert([1, 2, 3, 4, 5, 6], [17, 18, 9]) == [17, 18]
    pages, node = t.match([1, 2, 3, 4, 5, 6, 7])
    assert pages == [7, 8, 9]
    t.release(node)
    t.check_invariants()


@given(st.lists(st.tuples(st.integers(0, 3),
                          st.lists(st.integers(0, 3), min_size=0, max_size=12)),
                max_size=60))
@settings(max_examples=60, deadline=None)
def test_radix_property_invariants(ops):
    """Random interleavings of match/insert/release/evict keep: refcounts
    >= 0, every page owned exactly once (tree vs allocator), matches are
    true prefixes of prior inserts."""
    ps = 2
    t = RadixTree(ps)
    pool = PagePool(64)
    pinned = []                               # (node, tokens-match-len)
    inserted = {}                             # tuple(tokens blocks) -> page
    for kind, toks in ops:
        toks = list(toks)
        if kind == 0:                         # match + pin
            pages, node = t.match(toks)
            assert len(pages) <= len(toks) // ps
            # every matched page was inserted for exactly this block path
            for i, pg in enumerate(pages):
                key = tuple(toks[:(i + 1) * ps])
                assert inserted.get(key) == pg, (key, pg)
            pinned.append(node)
        elif kind == 1:                       # insert (simulate a prefill)
            n = len(toks) // ps
            pages = pool.alloc(n)
            if pages is None:
                continue
            rejected = t.insert(toks, pages)
            pool.free(rejected)
            kept = [p for p in pages if p not in rejected]
            for i in range(n):
                key = tuple(toks[:(i + 1) * ps])
                if pages[i] in kept:
                    inserted.setdefault(key, pages[i])
        elif kind == 2 and pinned:            # release one pin
            t.release(pinned.pop())
        else:                                 # evict
            freed = t.evict(len(toks) + 1)
            pool.free(freed)
            for key in [k for k, v in inserted.items() if v in set(freed)]:
                del inserted[key]
        owned = t.check_invariants()
        # exactly-once ownership: tree pages and free pages are disjoint and
        # account for every non-reserved page
        free = set(pool._free)
        assert not (owned & free)
        assert len(owned) + len(free) == pool.num_pages - pool.reserved
    for node in pinned:
        t.release(node)
    # with all pins dropped, everything is evictable
    pool.free(t.evict(10 ** 9))
    assert t.num_nodes == 0
    assert pool.num_free == pool.num_pages - pool.reserved


# ---------------------------------------------------------------------------
# paged decode-attention kernel vs dense reference (interpret mode)
# ---------------------------------------------------------------------------


def test_paged_kernel_matches_dense_reference():
    key = jax.random.PRNGKey(0)
    B, P, ps, K, G, hd = 3, 11, 8, 2, 2, 16
    k1, k2, k3 = jax.random.split(key, 3)
    kpool = jax.random.normal(k1, (P, ps, K, hd), jnp.float32)
    vpool = jax.random.normal(k2, (P, ps, K, hd), jnp.float32)
    q = jax.random.normal(k3, (B, 1, K * G, hd), jnp.float32)
    bt = jnp.asarray([[3, 1, 7, 10], [2, 5, 0, 0], [9, 8, 6, 4]], jnp.int32)
    clen = jnp.asarray([25, 10, 31], jnp.int32)
    out = paged_decode_attention(q, kpool, vpool, bt, clen, q_per_kv=G)
    ref = attn.decode_attention(q, attn.paged_view(kpool, bt),
                                attn.paged_view(vpool, bt), clen, q_per_kv=G)
    assert out.shape == ref.shape
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-5


def test_paged_cache_update_routes_through_block_table():
    P, ps, K, hd = 6, 4, 1, 2
    kpool = jnp.zeros((P, ps, K, hd))
    vpool = jnp.zeros((P, ps, K, hd))
    bt = jnp.asarray([[3, 1], [2, 5]], jnp.int32)
    knew = jnp.ones((2, 1, K, hd))
    clen = jnp.asarray([5, 2], jnp.int32)     # -> page 1 off 1, page 2 off 2
    kp, vp = attn.paged_cache_update(kpool, vpool, knew, 2 * knew, bt, clen, ps)
    assert float(kp[1, 1, 0, 0]) == 1.0 and float(vp[2, 2, 0, 0]) == 2.0
    assert float(jnp.sum(kp)) == 2 * K * hd   # one write per batch row


# ---------------------------------------------------------------------------
# engine equivalence: paged == dense greedy outputs, across archs
# ---------------------------------------------------------------------------

SYS = ("You are one of several cooperating agents sharing this exact system "
       "prompt and the same conversation history prefix. ")
TURNS = ["Plan the next step of the task.",
         "Act: call the search tool now.",
         "Evaluate the tool output please.",
         "Plan again with the new facts."]


@pytest.mark.parametrize("arch", PAGED_ARCHS)
def test_paged_equals_dense_greedy(arch):
    cfg = _cfg(arch)
    dense = ServingEngine(cfg, num_slots=3, capacity=128)
    paged = ServingEngine(cfg, num_slots=3, capacity=128, params=dense.params,
                          engine_cfg=EngineConfig(cache_mode="paged",
                                                  page_size=16))
    prompts = [SYS + t for t in TURNS]
    d = [dense.generate(p, max_new_tokens=8) for p in prompts]
    p = [paged.generate(p_, max_new_tokens=8) for p_ in prompts]
    assert d == p
    s = paged.stats()
    assert s["prefix_hit_tokens"] > 0         # later turns reused the prefix
    assert s["prefix_hit_rate"] > 0.2


def test_paged_mixed_batch_and_slot_reuse():
    """More requests than slots, interleaved shared/unshared prompts: FIFO
    admission, page recycling, and identical outputs vs dense."""
    cfg = _cfg("qwen2.5-3b")
    dense = ServingEngine(cfg, num_slots=2, capacity=96)
    paged = ServingEngine(cfg, num_slots=2, capacity=96, params=dense.params,
                          engine_cfg=EngineConfig(cache_mode="paged",
                                                  page_size=16))
    prompts = ([SYS + t for t in TURNS[:3]]
               + ["completely unrelated prompt about log analytics",
                  SYS + "Plan the next step of the task."])  # exact repeat
    for eng in (dense, paged):
        reqs = [eng.submit(p, max_new_tokens=6) for p in prompts]
        eng.run_until_drained()
        assert all(r.output_tokens == 6 for r in reqs)
    d = [dense.generate(p, max_new_tokens=6) for p in prompts]
    p = [paged.generate(p_, max_new_tokens=6) for p_ in prompts]
    assert d == p
    # the exact repeat matches everything but the final token's page
    last = paged.stats()
    assert last["prefix_hit_rate"] > 0
    # all pages accounted for after drain: free + retained-in-tree = usable
    assert (paged.kvpool.num_free + len(paged.radix.cached_pages)
            == paged.kvpool.num_pages - paged.kvpool.reserved)


def test_paged_pool_exhaustion_evicts_and_recovers():
    cfg = _cfg("qwen2.5-3b")
    eng = ServingEngine(cfg, num_slots=2, capacity=64,
                        engine_cfg=EngineConfig(cache_mode="paged",
                                                page_size=16, num_pages=9))
    reqs = [eng.submit(f"request number {i} with a shared tail of text",
                       max_new_tokens=8) for i in range(6)]
    eng.run_until_drained()
    assert all(r.output_tokens == 8 for r in reqs)
    assert eng.radix.evicted_pages > 0        # pressure forced LRU eviction
    eng.radix.check_invariants()
    # a request that can never fit raises instead of spinning
    tiny = ServingEngine(cfg, num_slots=1, capacity=64, params=eng.params,
                         engine_cfg=EngineConfig(cache_mode="paged",
                                                 page_size=16, num_pages=3))
    with pytest.raises(RuntimeError):
        tiny.generate("a prompt that needs more pages than the pool holds",
                      max_new_tokens=8)


def test_paged_sampling_determinism():
    """Stochastic decode: same seed + params -> same text in paged mode."""
    cfg = _cfg("qwen2.5-3b")
    e1 = ServingEngine(cfg, num_slots=2, capacity=96, seed=7,
                       engine_cfg=EngineConfig(cache_mode="paged"))
    e2 = ServingEngine(cfg, num_slots=2, capacity=96, params=e1.params, seed=7,
                       engine_cfg=EngineConfig(cache_mode="paged"))
    a = e1.generate("sample me", max_new_tokens=8, temperature=1.1, top_k=12)
    b = e2.generate("sample me", max_new_tokens=8, temperature=1.1, top_k=12)
    assert a == b
